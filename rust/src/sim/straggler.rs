//! Straggler injection: per-worker slowdown factors × sync modes.
//!
//! The cluster model in [`crate::sim::cluster`] assumes homogeneous
//! workers, which is exactly the assumption heterogeneous edge fleets
//! break — one thermally-throttled device makes every BSP barrier wait for
//! it. This module scores the synchronization subsystem's trade analytically
//! so `schedule_sensitivity` can sweep sync modes × straggler severity
//! without booting a real cluster (the real-wire counterpart is the
//! straggler matrix in `benches/ps_throughput.rs`):
//!
//! * **bsp** — every iteration ends at the slowest worker's pace; the
//!   fleet completes `n · k` iterations in `k · T_max`.
//! * **ssp(N)** — over a horizon of `k` slowest-worker iterations, a fast
//!   worker completes `min(wall / T_i, k + N)`: free-running until the
//!   staleness window stops it. The bound caps how much heterogeneity SSP
//!   can absorb — with `N = 0` it degenerates to BSP throughput exactly.
//! * **asp** — every worker free-runs: `Σ wall / T_i`.
//!
//! Iteration *throughput* is what relaxing consistency buys; what it
//! costs (gradient staleness) is bounded by `N` under SSP and unbounded
//! under ASP, which is why the sweep prints both.
//!
//! The **tier dimension** ([`TierSpec`]) overlays the hierarchical
//! aggregation topology (`ps::agg`, docs/TOPOLOGY.md) on the same
//! cluster: workers are chunked into groups behind regional aggregators,
//! each hop with its own sync mode. The fan-in is group-complete by
//! construction, so a group forwards at its slowest member's pace; the
//! cloud hop's mode then decides how far a clean group may run ahead of
//! the straggler's group, and the edge hop's mode how far a fast member
//! may run ahead of its own group. Cloud ingress shrinks by ~1/group
//! unconditionally — the throughput trade is what the sweep scores.

use crate::ps::sync::SyncMode;

/// A heterogeneous cluster: one base iteration time and per-worker
/// slowdown factors (1.0 = nominal; 4.0 = the classic 4× straggler).
#[derive(Debug, Clone)]
pub struct StragglerCluster {
    /// Nominal single-worker iteration wall-clock, ms (compute + comm).
    pub iter_ms: f64,
    /// Per-worker slowdown factors, all `>= 1`.
    pub slowdown: Vec<f64>,
}

/// Outcome of one (cluster, sync mode) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncThroughput {
    pub mode: SyncMode,
    /// Cluster-aggregate completed iterations over the horizon.
    pub iters: f64,
    /// Horizon wall-clock, ms.
    pub wall_ms: f64,
    /// Max iterations any worker ran ahead of the slowest (the staleness
    /// actually incurred: 0 under BSP, `<= bound` under SSP).
    pub max_lead: f64,
}

impl SyncThroughput {
    /// Completed iterations per second, cluster-aggregate.
    pub fn iters_per_sec(&self) -> f64 {
        self.iters / (self.wall_ms / 1e3)
    }
}

impl StragglerCluster {
    /// Uniform fleet with one worker slowed by `factor`.
    pub fn one_straggler(iter_ms: f64, workers: usize, factor: f64) -> StragglerCluster {
        assert!(workers >= 1 && factor >= 1.0);
        let mut slowdown = vec![1.0; workers];
        slowdown[0] = factor;
        StragglerCluster { iter_ms, slowdown }
    }

    fn t_max(&self) -> f64 {
        self.slowdown.iter().cloned().fold(f64::MIN, f64::max) * self.iter_ms
    }

    /// Throughput of `mode` over a horizon of `k_slow` slowest-worker
    /// iterations. `bound` is the SSP staleness window (ignored
    /// elsewhere).
    pub fn throughput(&self, mode: SyncMode, bound: u32, k_slow: u64) -> SyncThroughput {
        assert!(k_slow >= 1);
        let k = k_slow as f64;
        let wall_ms = k * self.t_max();
        let (iters, max_lead) = match mode {
            SyncMode::Bsp => (self.slowdown.len() as f64 * k, 0.0),
            SyncMode::Ssp => {
                let mut total = 0.0;
                let mut lead = 0.0f64;
                for s in &self.slowdown {
                    let free = wall_ms / (s * self.iter_ms);
                    let done = free.min(k + bound as f64);
                    total += done;
                    lead = lead.max(done - k);
                }
                (total, lead)
            }
            SyncMode::Asp => {
                let mut total = 0.0;
                let mut lead = 0.0f64;
                for s in &self.slowdown {
                    let done = wall_ms / (s * self.iter_ms);
                    total += done;
                    lead = lead.max(done - k);
                }
                (total, lead)
            }
        };
        SyncThroughput { mode, iters, wall_ms, max_lead }
    }

    /// `mode`'s iteration-throughput speedup over BSP on this cluster.
    pub fn speedup_vs_bsp(&self, mode: SyncMode, bound: u32, k_slow: u64) -> f64 {
        let bsp = self.throughput(SyncMode::Bsp, 0, k_slow);
        let it = self.throughput(mode, bound, k_slow);
        it.iters_per_sec() / bsp.iters_per_sec()
    }

    /// Throughput of the hierarchical topology `tier` over a horizon of
    /// `k_slow` slowest-worker iterations. Workers are chunked into
    /// groups of `tier.group_size` in `slowdown` order (a trailing
    /// partial group is fine). Per group:
    ///
    /// * the group's forwarding pace is its slowest member (the fan-in is
    ///   group-complete regardless of the edge-hop mode);
    /// * the **cloud** hop's mode bounds the group's completed
    ///   iterations: lockstep with the slowest group under `bsp`,
    ///   free-running within `cloud_bound` under `ssp`, free under `asp`;
    /// * the **edge** hop's mode bounds each member against its own
    ///   group's clock the same way.
    pub fn tiered_throughput(&self, tier: TierSpec, k_slow: u64) -> TierThroughput {
        assert!(k_slow >= 1 && tier.group_size >= 1);
        let k = k_slow as f64;
        let wall_ms = k * self.t_max();
        let groups: Vec<&[f64]> = self.slowdown.chunks(tier.group_size).collect();
        let mut iters = 0.0;
        let mut max_lead = 0.0f64;
        for g in &groups {
            let t_g = g.iter().cloned().fold(f64::MIN, f64::max) * self.iter_ms;
            let group_done = match tier.cloud_sync {
                SyncMode::Bsp => k,
                SyncMode::Ssp => (wall_ms / t_g).min(k + tier.cloud_bound as f64),
                SyncMode::Asp => wall_ms / t_g,
            };
            for s in *g {
                let free = wall_ms / (s * self.iter_ms);
                let done = match tier.edge_sync {
                    SyncMode::Bsp => group_done,
                    SyncMode::Ssp => free.min(group_done + tier.edge_bound as f64),
                    SyncMode::Asp => free,
                };
                iters += done;
                max_lead = max_lead.max(done - k);
            }
        }
        TierThroughput {
            iters,
            wall_ms,
            max_lead,
            cloud_ingress_ratio: groups.len() as f64 / self.slowdown.len() as f64,
        }
    }
}

/// The hierarchical-topology overlay for one tier-sweep cell: group size
/// plus an independent sync mode (and SSP bound) per hop, mirroring the
/// real tier's knobs (`--group-size`, `--sync`, `--agg-sync`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    /// Workers per regional aggregator (1 = every worker its own group —
    /// a pure relay).
    pub group_size: usize,
    /// edge → regional hop mode.
    pub edge_sync: SyncMode,
    /// SSP window on the edge hop (ignored elsewhere).
    pub edge_bound: u32,
    /// regional → cloud hop mode.
    pub cloud_sync: SyncMode,
    /// SSP window on the cloud hop (ignored elsewhere).
    pub cloud_bound: u32,
}

/// Outcome of one (cluster, tier) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierThroughput {
    /// Cluster-aggregate completed iterations over the horizon.
    pub iters: f64,
    /// Horizon wall-clock, ms.
    pub wall_ms: f64,
    /// Max iterations any worker ran ahead of the slowest.
    pub max_lead: f64,
    /// Pushes crossing the cloud boundary per fleet iteration, relative
    /// to the flat fleet: `groups / workers` (= `1 / group_size` when the
    /// fleet divides evenly).
    pub cloud_ingress_ratio: f64,
}

impl TierThroughput {
    /// Completed iterations per second, cluster-aggregate.
    pub fn iters_per_sec(&self) -> f64 {
        self.iters / (self.wall_ms / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn homogeneous_fleet_gains_nothing_from_relaxing() {
        let c = StragglerCluster { iter_ms: 10.0, slowdown: vec![1.0; 8] };
        for mode in SyncMode::ALL {
            assert!(close(c.speedup_vs_bsp(mode, 8, 16), 1.0), "{}", mode.name());
            assert!(close(c.throughput(mode, 8, 16).max_lead, 0.0));
        }
    }

    #[test]
    fn ssp_with_zero_bound_degenerates_to_bsp() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        assert!(close(c.speedup_vs_bsp(SyncMode::Ssp, 0, 12), 1.0));
    }

    #[test]
    fn relaxation_orders_throughput_bsp_ssp_asp() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let bsp = c.throughput(SyncMode::Bsp, 0, 12).iters_per_sec();
        let ssp = c.throughput(SyncMode::Ssp, 8, 12).iters_per_sec();
        let asp = c.throughput(SyncMode::Asp, 0, 12).iters_per_sec();
        assert!(bsp < ssp && ssp < asp, "bsp={bsp} ssp={ssp} asp={asp}");
        // And SSP throughput is monotone in the bound, capped by ASP.
        let mut last = bsp;
        for bound in [0u32, 2, 4, 8, 16, 1 << 20] {
            let t = c.throughput(SyncMode::Ssp, bound, 12).iters_per_sec();
            assert!(t >= last - 1e-12, "bound {bound}: {t} < {last}");
            assert!(t <= asp + 1e-12);
            last = t;
        }
    }

    #[test]
    fn ssp_respects_its_staleness_bound() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        for bound in [0u32, 1, 3, 7] {
            let t = c.throughput(SyncMode::Ssp, bound, 12);
            assert!(
                t.max_lead <= bound as f64 + 1e-12,
                "bound {bound}: lead {}",
                t.max_lead
            );
        }
        // ASP's lead is unbounded by anything but the horizon.
        let t = c.throughput(SyncMode::Asp, 0, 12);
        assert!(t.max_lead > 7.0);
    }

    /// The acceptance-shaped cell: one 4×-slowed worker in an 8-fleet —
    /// SSP with a window that merely covers the horizon's skew recovers
    /// well over 1.5× BSP iteration throughput.
    #[test]
    fn four_x_straggler_ssp_recovers_1p5x() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let s = c.speedup_vs_bsp(SyncMode::Ssp, 8, 4);
        assert!(s >= 1.5, "ssp speedup {s}");
        let a = c.speedup_vs_bsp(SyncMode::Asp, 0, 4);
        assert!(a >= s);
    }

    fn tier(gs: usize, edge: SyncMode, eb: u32, cloud: SyncMode, cb: u32) -> TierSpec {
        TierSpec {
            group_size: gs,
            edge_sync: edge,
            edge_bound: eb,
            cloud_sync: cloud,
            cloud_bound: cb,
        }
    }

    #[test]
    fn tiered_bsp_both_hops_matches_flat_bsp_at_any_group_size() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let flat = c.throughput(SyncMode::Bsp, 0, 8);
        for gs in [1usize, 2, 3, 4, 8] {
            let t = c.tiered_throughput(tier(gs, SyncMode::Bsp, 0, SyncMode::Bsp, 0), 8);
            assert!(close(t.iters, flat.iters), "gs {gs}: {} vs {}", t.iters, flat.iters);
            assert!(close(t.max_lead, 0.0));
        }
    }

    #[test]
    fn group_size_one_with_bsp_edge_reduces_to_the_flat_cloud_mode() {
        // A one-member group is a pure relay: its forwarding pace is its
        // sole member, so the cloud hop's mode sees exactly the flat
        // fleet — the overlay must not distort the baseline.
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        for mode in SyncMode::ALL {
            let bound = if mode == SyncMode::Ssp { 8 } else { 0 };
            let flat = c.throughput(mode, bound, 8);
            let t = c.tiered_throughput(tier(1, SyncMode::Bsp, 0, mode, bound), 8);
            assert!(close(t.iters, flat.iters), "{}: {} vs {}", mode.name(), t.iters, flat.iters);
        }
    }

    #[test]
    fn cloud_ingress_shrinks_with_the_group_size() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        for (gs, expect) in [(1usize, 1.0), (2, 0.5), (4, 0.25), (8, 0.125)] {
            let t = c.tiered_throughput(tier(gs, SyncMode::Bsp, 0, SyncMode::Bsp, 0), 8);
            assert!(close(t.cloud_ingress_ratio, expect), "gs {gs}");
        }
        // A trailing partial group still counts as a group.
        let t = c.tiered_throughput(tier(3, SyncMode::Bsp, 0, SyncMode::Bsp, 0), 8);
        assert!(close(t.cloud_ingress_ratio, 3.0 / 8.0));
    }

    #[test]
    fn tiering_contains_the_straggler_to_its_own_group() {
        // One 4× straggler, groups of 4, BSP edge + SSP cloud: the
        // straggler's three group-mates are captive behind the group
        // fan-in, but the clean group runs within the cloud window — the
        // fleet lands strictly between flat BSP and flat SSP.
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let flat_bsp = c.throughput(SyncMode::Bsp, 0, 8).iters_per_sec();
        let flat_ssp = c.throughput(SyncMode::Ssp, 8, 8).iters_per_sec();
        let t = c.tiered_throughput(tier(4, SyncMode::Bsp, 0, SyncMode::Ssp, 8), 8);
        let tiered = t.iters_per_sec();
        assert!(
            flat_bsp < tiered && tiered < flat_ssp,
            "bsp={flat_bsp} tiered={tiered} ssp={flat_ssp}"
        );
        assert!(t.max_lead <= 8.0 + 1e-12, "cloud window broken: {}", t.max_lead);
    }

    #[test]
    fn relaxing_the_edge_hop_is_monotone() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        for cloud in SyncMode::ALL {
            let cb = if cloud == SyncMode::Ssp { 8 } else { 0 };
            let bsp = c.tiered_throughput(tier(4, SyncMode::Bsp, 0, cloud, cb), 8);
            let ssp = c.tiered_throughput(tier(4, SyncMode::Ssp, 2, cloud, cb), 8);
            let asp = c.tiered_throughput(tier(4, SyncMode::Asp, 0, cloud, cb), 8);
            assert!(
                bsp.iters <= ssp.iters + 1e-12 && ssp.iters <= asp.iters + 1e-12,
                "cloud {}: bsp={} ssp={} asp={}",
                cloud.name(),
                bsp.iters,
                ssp.iters,
                asp.iters
            );
        }
    }
}
