//! Straggler injection: per-worker slowdown factors × sync modes.
//!
//! The cluster model in [`crate::sim::cluster`] assumes homogeneous
//! workers, which is exactly the assumption heterogeneous edge fleets
//! break — one thermally-throttled device makes every BSP barrier wait for
//! it. This module scores the synchronization subsystem's trade analytically
//! so `schedule_sensitivity` can sweep sync modes × straggler severity
//! without booting a real cluster (the real-wire counterpart is the
//! straggler matrix in `benches/ps_throughput.rs`):
//!
//! * **bsp** — every iteration ends at the slowest worker's pace; the
//!   fleet completes `n · k` iterations in `k · T_max`.
//! * **ssp(N)** — over a horizon of `k` slowest-worker iterations, a fast
//!   worker completes `min(wall / T_i, k + N)`: free-running until the
//!   staleness window stops it. The bound caps how much heterogeneity SSP
//!   can absorb — with `N = 0` it degenerates to BSP throughput exactly.
//! * **asp** — every worker free-runs: `Σ wall / T_i`.
//!
//! Iteration *throughput* is what relaxing consistency buys; what it
//! costs (gradient staleness) is bounded by `N` under SSP and unbounded
//! under ASP, which is why the sweep prints both.

use crate::ps::sync::SyncMode;

/// A heterogeneous cluster: one base iteration time and per-worker
/// slowdown factors (1.0 = nominal; 4.0 = the classic 4× straggler).
#[derive(Debug, Clone)]
pub struct StragglerCluster {
    /// Nominal single-worker iteration wall-clock, ms (compute + comm).
    pub iter_ms: f64,
    /// Per-worker slowdown factors, all `>= 1`.
    pub slowdown: Vec<f64>,
}

/// Outcome of one (cluster, sync mode) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncThroughput {
    pub mode: SyncMode,
    /// Cluster-aggregate completed iterations over the horizon.
    pub iters: f64,
    /// Horizon wall-clock, ms.
    pub wall_ms: f64,
    /// Max iterations any worker ran ahead of the slowest (the staleness
    /// actually incurred: 0 under BSP, `<= bound` under SSP).
    pub max_lead: f64,
}

impl SyncThroughput {
    /// Completed iterations per second, cluster-aggregate.
    pub fn iters_per_sec(&self) -> f64 {
        self.iters / (self.wall_ms / 1e3)
    }
}

impl StragglerCluster {
    /// Uniform fleet with one worker slowed by `factor`.
    pub fn one_straggler(iter_ms: f64, workers: usize, factor: f64) -> StragglerCluster {
        assert!(workers >= 1 && factor >= 1.0);
        let mut slowdown = vec![1.0; workers];
        slowdown[0] = factor;
        StragglerCluster { iter_ms, slowdown }
    }

    fn t_max(&self) -> f64 {
        self.slowdown.iter().cloned().fold(f64::MIN, f64::max) * self.iter_ms
    }

    /// Throughput of `mode` over a horizon of `k_slow` slowest-worker
    /// iterations. `bound` is the SSP staleness window (ignored
    /// elsewhere).
    pub fn throughput(&self, mode: SyncMode, bound: u32, k_slow: u64) -> SyncThroughput {
        assert!(k_slow >= 1);
        let k = k_slow as f64;
        let wall_ms = k * self.t_max();
        let (iters, max_lead) = match mode {
            SyncMode::Bsp => (self.slowdown.len() as f64 * k, 0.0),
            SyncMode::Ssp => {
                let mut total = 0.0;
                let mut lead = 0.0f64;
                for s in &self.slowdown {
                    let free = wall_ms / (s * self.iter_ms);
                    let done = free.min(k + bound as f64);
                    total += done;
                    lead = lead.max(done - k);
                }
                (total, lead)
            }
            SyncMode::Asp => {
                let mut total = 0.0;
                let mut lead = 0.0f64;
                for s in &self.slowdown {
                    let done = wall_ms / (s * self.iter_ms);
                    total += done;
                    lead = lead.max(done - k);
                }
                (total, lead)
            }
        };
        SyncThroughput { mode, iters, wall_ms, max_lead }
    }

    /// `mode`'s iteration-throughput speedup over BSP on this cluster.
    pub fn speedup_vs_bsp(&self, mode: SyncMode, bound: u32, k_slow: u64) -> f64 {
        let bsp = self.throughput(SyncMode::Bsp, 0, k_slow);
        let it = self.throughput(mode, bound, k_slow);
        it.iters_per_sec() / bsp.iters_per_sec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn homogeneous_fleet_gains_nothing_from_relaxing() {
        let c = StragglerCluster { iter_ms: 10.0, slowdown: vec![1.0; 8] };
        for mode in SyncMode::ALL {
            assert!(close(c.speedup_vs_bsp(mode, 8, 16), 1.0), "{}", mode.name());
            assert!(close(c.throughput(mode, 8, 16).max_lead, 0.0));
        }
    }

    #[test]
    fn ssp_with_zero_bound_degenerates_to_bsp() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        assert!(close(c.speedup_vs_bsp(SyncMode::Ssp, 0, 12), 1.0));
    }

    #[test]
    fn relaxation_orders_throughput_bsp_ssp_asp() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let bsp = c.throughput(SyncMode::Bsp, 0, 12).iters_per_sec();
        let ssp = c.throughput(SyncMode::Ssp, 8, 12).iters_per_sec();
        let asp = c.throughput(SyncMode::Asp, 0, 12).iters_per_sec();
        assert!(bsp < ssp && ssp < asp, "bsp={bsp} ssp={ssp} asp={asp}");
        // And SSP throughput is monotone in the bound, capped by ASP.
        let mut last = bsp;
        for bound in [0u32, 2, 4, 8, 16, 1 << 20] {
            let t = c.throughput(SyncMode::Ssp, bound, 12).iters_per_sec();
            assert!(t >= last - 1e-12, "bound {bound}: {t} < {last}");
            assert!(t <= asp + 1e-12);
            last = t;
        }
    }

    #[test]
    fn ssp_respects_its_staleness_bound() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        for bound in [0u32, 1, 3, 7] {
            let t = c.throughput(SyncMode::Ssp, bound, 12);
            assert!(
                t.max_lead <= bound as f64 + 1e-12,
                "bound {bound}: lead {}",
                t.max_lead
            );
        }
        // ASP's lead is unbounded by anything but the horizon.
        let t = c.throughput(SyncMode::Asp, 0, 12);
        assert!(t.max_lead > 7.0);
    }

    /// The acceptance-shaped cell: one 4×-slowed worker in an 8-fleet —
    /// SSP with a window that merely covers the horizon's skew recovers
    /// well over 1.5× BSP iteration throughput.
    #[test]
    fn four_x_straggler_ssp_recovers_1p5x() {
        let c = StragglerCluster::one_straggler(10.0, 8, 4.0);
        let s = c.speedup_vs_bsp(SyncMode::Ssp, 8, 4);
        assert!(s >= 1.5, "ssp speedup {s}");
        let a = c.speedup_vs_bsp(SyncMode::Asp, 0, 4);
        assert!(a >= s);
    }
}
