//! Random profiling-result generator (Section V-B "we randomly generated a
//! series of profiling results with different numbers of network layers" —
//! Fig. 12's input, also used by the property tests).

use crate::sched::CostVectors;
use crate::util::rng::Rng;

/// Shape of the generated per-layer cost distribution.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadParams {
    /// Log-space mean of per-layer transmission cost (ms).
    pub comm_mu: f64,
    /// Log-space mean of per-layer computation cost (ms).
    pub comp_mu: f64,
    /// Log-space sigma — CNN layer costs are heavy-tailed (conv vs fc).
    pub sigma: f64,
    /// Δt, ms.
    pub delta_t: f64,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        // Centered on the paper's regime: layer costs of a few ms,
        // Δt + latency ≈ 14 ms.
        WorkloadParams { comm_mu: 0.7, comp_mu: 0.7, sigma: 1.2, delta_t: 14.0 }
    }
}

/// Generate a random profile with `depth` layers.
pub fn generate(rng: &mut Rng, depth: usize, p: WorkloadParams) -> CostVectors {
    let mut pt = Vec::with_capacity(depth);
    let mut fc = Vec::with_capacity(depth);
    let mut bc = Vec::with_capacity(depth);
    let mut gt = Vec::with_capacity(depth);
    for _ in 0..depth {
        let t = rng.lognormal(p.comm_mu, p.sigma);
        pt.push(t);
        gt.push(t); // gradients mirror parameter sizes
        let c = rng.lognormal(p.comp_mu, p.sigma);
        fc.push(c);
        bc.push(2.0 * c); // backward ≈ 2x forward
    }
    CostVectors { pt, fc, bc, gt, delta_t: p.delta_t }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_depth() {
        let mut rng = Rng::new(61);
        for depth in [1, 10, 160, 320] {
            let cv = generate(&mut rng, depth, WorkloadParams::default());
            assert_eq!(cv.depth(), depth);
            cv.validate().unwrap();
        }
    }

    #[test]
    fn bwd_is_double_fwd() {
        let mut rng = Rng::new(62);
        let cv = generate(&mut rng, 50, WorkloadParams::default());
        for (f, b) in cv.fc.iter().zip(&cv.bc) {
            assert!((b / f - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&mut Rng::new(7), 20, WorkloadParams::default());
        let b = generate(&mut Rng::new(7), 20, WorkloadParams::default());
        assert_eq!(a, b);
    }
}
