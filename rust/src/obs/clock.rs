//! Clock alignment: NTP-style four-timestamp probes over the fleet's
//! existing TCP sessions, so the merged trace ([`super::trace`]) can put
//! every node's spans on one corrected timeline.
//!
//! A probe is one `ClockProbe`/`ClockReply` exchange (wire v7, opcodes
//! 15/16 — see docs/WIRE.md): the prober stamps `t1` at send, the
//! responder echoes it with its own receive (`t2`) and send (`t3`)
//! stamps, and the prober stamps `t4` at receipt. Standard NTP algebra
//! then gives
//!
//! ```text
//! offset      = ((t2 - t1) + (t3 - t4)) / 2     (peer clock - local clock)
//! uncertainty = ((t4 - t1) - (t3 - t2)) / 2     (half the pure RTT)
//! ```
//!
//! under the usual symmetric-path assumption; the uncertainty is the
//! half-RTT error bound that assumption leaves. Probes run at session
//! establish and periodically after ([`probe_and_note`] keeps the
//! minimum-uncertainty sample of a burst, the classic NTP filter), and
//! measured offsets land in a process-global per-peer store consumed by
//! trace export ([`node_offset_ns`]) and exposed as the
//! `dynacomm_clock_offset_us` / `dynacomm_clock_uncertainty_us` gauges.

use std::sync::{Mutex, OnceLock};

use anyhow::Context;

use crate::net::{Connection, Message, MessageRef};
use crate::obs::Gauge;
use crate::util::sync::lock_or_die;

/// One four-timestamp clock measurement against a peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClockSample {
    /// Estimated `peer_clock - local_clock`, nanoseconds.
    pub offset_ns: i64,
    /// Error bound on the offset (half the pure round-trip), nanoseconds.
    pub uncertainty_ns: i64,
}

/// NTP offset/uncertainty from the four timestamps: `t1` probe send and
/// `t4` reply receive on the local clock, `t2` probe receive and `t3`
/// reply send on the peer's clock (all nanoseconds).
pub fn sample_from(t1: u64, t2: u64, t3: u64, t4: u64) -> ClockSample {
    let (t1, t2, t3, t4) = (t1 as i64, t2 as i64, t3 as i64, t4 as i64);
    ClockSample {
        offset_ns: ((t2 - t1) + (t3 - t4)) / 2,
        // Clamped: a peer that reports t3 < t2 (can't happen with honest
        // clocks) must not produce a negative error bound.
        uncertainty_ns: ((t4 - t1) - (t3 - t2)).max(0) / 2,
    }
}

/// Run one probe over an established session. The caller must be at a
/// lock-step point in its request/reply protocol (no other request in
/// flight), which is exactly where workers and aggregators call it:
/// right after session establish and between iterations.
pub fn probe(conn: &mut Connection) -> anyhow::Result<ClockSample> {
    let t1 = super::trace::now_ns();
    conn.send(&Message::ClockProbe { t1 }).context("sending clock probe")?;
    let reply = conn.recv_ref().context("receiving clock reply")?;
    let t4 = super::trace::now_ns();
    match reply {
        MessageRef::ClockReply { t1: echoed, t2, t3 } => {
            anyhow::ensure!(
                echoed == t1,
                "clock reply echoes t1={echoed}, probe sent t1={t1}"
            );
            Ok(sample_from(t1, t2, t3, t4))
        }
        other => anyhow::bail!("expected ClockReply to clock probe, got opcode {}", other.opcode()),
    }
}

/// Probe `rounds` times and record the minimum-uncertainty sample for
/// `node` (the NTP sample filter: the tightest round-trip bounds the
/// offset best). Returns the kept sample.
pub fn probe_and_note(
    conn: &mut Connection,
    node: &str,
    rounds: usize,
) -> anyhow::Result<ClockSample> {
    let mut best: Option<ClockSample> = None;
    for _ in 0..rounds.max(1) {
        let s = probe(conn)?;
        if best.map_or(true, |b| s.uncertainty_ns < b.uncertainty_ns) {
            best = Some(s);
        }
    }
    let best = best.expect("rounds.max(1) probes ran");
    note_node_offset(node, best.offset_ns, best.uncertainty_ns);
    Ok(best)
}

/// Per-peer clock state: the latest accepted offset plus the pair of
/// gauges that exposes it. Gauges live here for the process lifetime, so
/// the series survive between scrapes.
struct PeerClock {
    node: String,
    offset_ns: i64,
    offset_us: Gauge,
    uncertainty_us: Gauge,
}

fn store() -> &'static Mutex<Vec<PeerClock>> {
    static PEERS: OnceLock<Mutex<Vec<PeerClock>>> = OnceLock::new();
    PEERS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Record a measured clock offset for `node`, creating the peer's gauge
/// pair on first sight and updating it after. Called by
/// [`probe_and_note`]; tests and the trainer (which aggregates offsets
/// reported by workers) call it directly.
pub fn note_node_offset(node: &str, offset_ns: i64, uncertainty_ns: i64) {
    let mut peers = lock_or_die(store(), "obs.clock");
    let idx = match peers.iter().position(|p| p.node == node) {
        Some(i) => i,
        None => {
            let inst = crate::obs::next_inst();
            let labels = format!("peer=\"{node}\"");
            peers.push(PeerClock {
                node: node.to_string(),
                offset_ns: 0,
                offset_us: crate::obs_gauge!("dynacomm_clock_offset_us", labels, inst),
                uncertainty_us: crate::obs_gauge!("dynacomm_clock_uncertainty_us", labels, inst),
            });
            peers.len() - 1
        }
    };
    let peer = &mut peers[idx];
    peer.offset_ns = offset_ns;
    peer.offset_us.set(offset_ns as f64 / 1e3);
    peer.uncertainty_us.set(uncertainty_ns as f64 / 1e3);
}

/// The latest measured offset for `node` (0 if never probed): what trace
/// export subtracts from that node's lane to land it on the prober's
/// timeline.
pub fn node_offset_ns(node: &str) -> i64 {
    lock_or_die(store(), "obs.clock")
        .iter()
        .find(|p| p.node == node)
        .map(|p| p.offset_ns)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ntp_algebra_recovers_offset_and_rtt() {
        // Peer clock runs 500ns ahead; 40ns out, 60ns back on the wire.
        // t1=1000, t2=(1000+40)+500, t3=t2+10 (peer hold), and
        // t4=1000+40+10+60 back on the local clock.
        let s = sample_from(1_000, 1_540, 1_550, 1_110);
        // offset = ((1540-1000) + (1550-1110))/2 = 490: the true 500 minus
        // the (40-60)/2 path-asymmetry error, inside the uncertainty.
        assert_eq!(s.offset_ns, 490);
        // uncertainty = ((1110-1000) - 10)/2 = half the pure 100ns RTT.
        assert_eq!(s.uncertainty_ns, 50);

        // Negative offset (peer behind) comes out signed.
        let s = sample_from(2_000, 1_600, 1_610, 3_010);
        assert!(s.offset_ns < 0, "peer behind must yield negative offset");
        assert_eq!(s.uncertainty_ns, 500);

        // A dishonest t3 < t2 clamps to a non-negative bound.
        let s = sample_from(0, 100, 50, 10);
        assert!(s.uncertainty_ns >= 0);
    }

    #[test]
    fn offsets_update_in_place_and_export_gauges() {
        note_node_offset("clock-test-a", 7_000, 2_000);
        note_node_offset("clock-test-b", -3_000, 1_000);
        assert_eq!(node_offset_ns("clock-test-a"), 7_000);
        assert_eq!(node_offset_ns("clock-test-b"), -3_000);
        assert_eq!(node_offset_ns("clock-test-never-probed"), 0);

        // Re-noting the same peer updates the entry instead of duplicating.
        note_node_offset("clock-test-a", 9_000, 500);
        assert_eq!(node_offset_ns("clock-test-a"), 9_000);
        let text = crate::obs::render_prometheus();
        let rows: Vec<&str> = text
            .lines()
            .filter(|l| {
                l.starts_with("dynacomm_clock_offset_us{") && l.contains("peer=\"clock-test-a\"")
            })
            .collect();
        assert_eq!(rows.len(), 1, "one series per peer, updated in place: {rows:?}");
        assert!(rows[0].ends_with(" 9"), "9000ns -> 9us: {}", rows[0]);
        assert!(
            text.lines().any(|l| l.starts_with("dynacomm_clock_uncertainty_us{")
                && l.contains("peer=\"clock-test-a\"")
                && l.ends_with(" 0.5")),
            "uncertainty gauge in us:\n{text}"
        );
    }
}
