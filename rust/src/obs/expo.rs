//! Exposition: a tiny hand-rolled HTTP/1.1 listener serving Prometheus
//! text-format snapshots of the whole registry (`--metrics-addr`). No
//! crates; routing is exact-path: `/metrics` scrapes, `/healthz` reports
//! liveness, anything else is a 404.
//!
//! The trainer also federates member snapshots here: end-of-run
//! `snapshot_pairs()` from each worker are re-exported from the trainer's
//! endpoint with a `node="worker-N"` label prepended, so one scrape sees
//! the whole fleet (docs/OBSERVABILITY.md, "Fleet federation").

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

/// Background scrape endpoint. Binds eagerly (so `127.0.0.1:0` reports the
/// picked port via [`MetricsServer::addr`]) and serves one request per
/// connection until dropped or [`MetricsServer::shutdown`].
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    pub fn bind(addr: &str) -> anyhow::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener on {addr}"))?;
        let local = listener.local_addr().context("metrics listener addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let started = Instant::now();
        let handle = std::thread::Builder::new()
            .name("obs-expo".into())
            .spawn(move || serve(listener, stop2, started))
            .context("spawning metrics listener thread")?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolved port for `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Member snapshots re-exported from this process's scrape endpoint,
/// keyed by node name. Replace-on-re-note per node; process-global so a
/// re-bound server keeps previously noted members.
fn federated() -> &'static Mutex<BTreeMap<String, Vec<(String, f64)>>> {
    static STORE: OnceLock<Mutex<BTreeMap<String, Vec<(String, f64)>>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record (or replace) a member node's metrics snapshot for federation.
/// `pairs` are rendered-series pairs as produced by
/// [`crate::obs::snapshot_pairs`]; scrapes re-emit each with a
/// `node="{node}"` label prepended to the series' label set.
pub fn note_federated(node: &str, pairs: Vec<(String, f64)>) {
    crate::util::sync::lock_or_die(federated(), "obs.federated").insert(node.to_string(), pairs);
}

/// Render the federation store as exposition rows. Series names arrive
/// already rendered (`name{labels}`), so the node label is spliced in as
/// the first label rather than re-deriving the set.
fn render_federated() -> String {
    let mut out = String::new();
    let store = crate::util::sync::lock_or_die(federated(), "obs.federated");
    for (node, pairs) in store.iter() {
        for (series, value) in pairs {
            match series.find('{') {
                Some(brace) if series.ends_with("{}") => {
                    out.push_str(&format!("{}{{node=\"{node}\"}} {value}\n", &series[..brace]));
                }
                Some(brace) => {
                    let (name, labels) = series.split_at(brace + 1);
                    out.push_str(&format!("{name}node=\"{node}\",{labels} {value}\n"));
                }
                // Bare series name (no labels rendered at all).
                None => out.push_str(&format!("{series}{{node=\"{node}\"}} {value}\n")),
            }
        }
    }
    out
}

fn serve(listener: TcpListener, stop: Arc<AtomicBool>, started: Instant) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = conn else { continue };
        let _ = handle_one(&mut stream, started);
    }
}

/// First line of an HTTP/1.x request head → the request path (query
/// string stripped), or `/` when the head is malformed.
fn request_path(head: &[u8]) -> &str {
    let line = match head.iter().position(|&b| b == b'\r' || b == b'\n') {
        Some(end) => &head[..end],
        None => head,
    };
    let line = std::str::from_utf8(line).unwrap_or("");
    let path = line.split(' ').nth(1).unwrap_or("/");
    path.split('?').next().unwrap_or("/")
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let response = format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

fn handle_one(stream: &mut TcpStream, started: Instant) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    // Drain the request head (bounded).
    let mut head = [0u8; 4096];
    let mut seen = 0usize;
    while seen < head.len() {
        let n = stream.read(&mut head[seen..])?;
        if n == 0 {
            break;
        }
        seen += n;
        if head[..seen].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    match request_path(&head[..seen]) {
        "/metrics" => {
            let mut body = super::render_prometheus();
            body.push_str(&render_federated());
            write_response(stream, "200 OK", "text/plain; version=0.0.4", &body)
        }
        "/healthz" => {
            // Liveness probe: uptime plus how many series a scrape would
            // currently render (local registry + federated members).
            let series = super::snapshot_pairs().len()
                + crate::util::sync::lock_or_die(federated(), "obs.federated")
                    .values()
                    .map(Vec::len)
                    .sum::<usize>();
            let body = format!(
                "{{\"uptime_s\":{:.3},\"series\":{series}}}\n",
                started.elapsed().as_secs_f64()
            );
            write_response(stream, "200 OK", "application/json", &body)
        }
        _ => write_response(stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

/// Scrape `addr` once over plain HTTP and return the exposition body.
/// Used by tests, the CI e2e job, and the bench harness.
pub fn scrape(addr: SocketAddr) -> anyhow::Result<String> {
    let (status, body) = http_get(addr, "/metrics")?;
    anyhow::ensure!(status == 200, "scrape returned non-200: {status}");
    Ok(body)
}

/// One GET over plain HTTP; returns `(status code, body)`. Public so the
/// integration tests and CI e2e can hit `/healthz` and probe 404s.
pub fn http_get(addr: SocketAddr, path: &str) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to metrics endpoint {addr}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("scrape read timeout")?;
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: dynacomm\r\nConnection: close\r\n\r\n").as_bytes())
        .context("writing scrape request")?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .context("reading scrape response")?;
    let split = raw
        .find("\r\n\r\n")
        .context("scrape response missing header/body separator")?;
    let status: u16 = raw
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("scrape response missing status code")?;
    Ok((status, raw[split + 4..].to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_scrape_and_shutdown() {
        let counter =
            crate::obs::register_counter("dynacomm_test_expo", "", crate::obs::next_inst());
        counter.add(11);
        let mut srv = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let body = scrape(srv.addr()).expect("scrape");
        assert!(body.contains("# TYPE dynacomm_test_expo counter"));
        assert!(
            body.lines()
                .any(|l| l.starts_with("dynacomm_test_expo{") && l.ends_with(" 11")),
            "series row missing:\n{body}"
        );
        srv.shutdown();
        srv.shutdown(); // idempotent
        assert!(TcpStream::connect(srv.addr()).is_err() || scrape(srv.addr()).is_err());
    }

    #[test]
    fn healthz_and_unknown_paths() {
        let _g = crate::obs::register_gauge("dynacomm_test_healthz", "", crate::obs::next_inst());
        let srv = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let (status, body) = http_get(srv.addr(), "/healthz").expect("healthz");
        assert_eq!(status, 200);
        let json = crate::util::json::Json::parse(&body).expect("healthz body is JSON");
        assert!(json.get("uptime_s").and_then(|v| v.as_f64()).expect("uptime_s") >= 0.0);
        assert!(json.get("series").and_then(|v| v.as_f64()).expect("series") >= 1.0);
        let (status, _) = http_get(srv.addr(), "/nope").expect("404 path");
        assert_eq!(status, 404);
        let (status, _) = http_get(srv.addr(), "/").expect("root path");
        assert_eq!(status, 404);
    }

    #[test]
    fn federated_rows_carry_node_label() {
        let counter =
            crate::obs::register_counter("dynacomm_test_fed_local", "", crate::obs::next_inst());
        counter.add(3);
        note_federated(
            "worker-7",
            vec![
                ("dynacomm_test_fed_member{inst=\"0\"}".to_string(), 42.0),
                ("dynacomm_test_fed_bare".to_string(), 1.0),
            ],
        );
        let srv = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let body = scrape(srv.addr()).expect("scrape");
        assert!(
            body.contains("dynacomm_test_fed_member{node=\"worker-7\",inst=\"0\"} 42"),
            "federated row missing node label:\n{body}"
        );
        assert!(
            body.contains("dynacomm_test_fed_bare{node=\"worker-7\"} 1"),
            "bare federated row missing:\n{body}"
        );
        // Replace-on-re-note: a fresh snapshot fully supersedes the old one.
        note_federated(
            "worker-7",
            vec![("dynacomm_test_fed_member{inst=\"0\"}".to_string(), 43.0)],
        );
        let body = scrape(srv.addr()).expect("rescrape");
        assert!(body.contains("dynacomm_test_fed_member{node=\"worker-7\",inst=\"0\"} 43"));
        assert!(!body.contains("dynacomm_test_fed_bare{node=\"worker-7\"}"));
    }

    /// A live scrape racing instance churn (drop + re-register) must never
    /// panic the listener or render a torn series: every non-comment line
    /// is a complete `name{labels} value` row with a parseable value.
    #[test]
    fn scrape_under_instance_churn() {
        let srv = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let churn = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                let c = crate::obs::register_counter(
                    "dynacomm_test_churn",
                    "",
                    crate::obs::next_inst(),
                );
                c.add(1);
                // Dropping the handle kills the weak registry entry; the
                // next registration takes a fresh inst id.
            }
        });
        for _ in 0..50 {
            let body = scrape(srv.addr()).expect("scrape during churn");
            for line in body.lines() {
                if line.starts_with('#') || line.is_empty() {
                    continue;
                }
                let (series, value) = line
                    .rsplit_once(' ')
                    .unwrap_or_else(|| panic!("torn series row: {line:?}"));
                assert!(
                    value.parse::<f64>().is_ok(),
                    "unparseable value in row: {line:?}"
                );
                assert!(
                    !series.contains('{') || series.contains('}'),
                    "unterminated label set: {line:?}"
                );
            }
        }
        stop.store(true, Ordering::Relaxed);
        churn.join().expect("churn thread");
    }
}
