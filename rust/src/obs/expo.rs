//! Exposition: a tiny hand-rolled HTTP/1.1 listener serving Prometheus
//! text-format snapshots of the whole registry (`--metrics-addr`). No
//! crates, no routing — every request gets the full scrape body.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context;

/// Background scrape endpoint. Binds eagerly (so `127.0.0.1:0` reports the
/// picked port via [`MetricsServer::addr`]) and serves one request per
/// connection until dropped or [`MetricsServer::shutdown`].
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    pub fn bind(addr: &str) -> anyhow::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener on {addr}"))?;
        let local = listener.local_addr().context("metrics listener addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("obs-expo".into())
            .spawn(move || serve(listener, stop2))
            .context("spawning metrics listener thread")?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolved port for `:0` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve(listener: TcpListener, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = conn else { continue };
        let _ = handle_one(&mut stream);
    }
}

fn handle_one(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    // Drain the request head (bounded); the path is ignored — every
    // request is a scrape.
    let mut head = [0u8; 4096];
    let mut seen = 0usize;
    while seen < head.len() {
        let n = stream.read(&mut head[seen..])?;
        if n == 0 {
            break;
        }
        seen += n;
        if head[..seen].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let body = super::render_prometheus();
    let response = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Scrape `addr` once over plain HTTP and return the exposition body.
/// Used by tests, the CI e2e job, and the bench harness.
pub fn scrape(addr: SocketAddr) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to metrics endpoint {addr}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .context("scrape read timeout")?;
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: dynacomm\r\nConnection: close\r\n\r\n")
        .context("writing scrape request")?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .context("reading scrape response")?;
    let split = raw
        .find("\r\n\r\n")
        .context("scrape response missing header/body separator")?;
    anyhow::ensure!(
        raw.starts_with("HTTP/1.1 200"),
        "scrape returned non-200: {}",
        raw.lines().next().unwrap_or("")
    );
    Ok(raw[split + 4..].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_scrape_and_shutdown() {
        let counter =
            crate::obs::register_counter("dynacomm_test_expo", "", crate::obs::next_inst());
        counter.add(11);
        let mut srv = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let body = scrape(srv.addr()).expect("scrape");
        assert!(body.contains("# TYPE dynacomm_test_expo counter"));
        assert!(
            body.lines()
                .any(|l| l.starts_with("dynacomm_test_expo{") && l.ends_with(" 11")),
            "series row missing:\n{body}"
        );
        srv.shutdown();
        srv.shutdown(); // idempotent
        assert!(TcpStream::connect(srv.addr()).is_err() || scrape(srv.addr()).is_err());
    }
}
