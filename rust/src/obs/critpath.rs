//! Critical-path analysis: an offline pass over the merged fleet trace
//! ([`super::trace::chrome_trace_json`]) that answers the question the
//! scheduler's predictions only approximate — *which hop actually
//! dominated each iteration?*
//!
//! The analyzer works by exhaustive gap accounting rather than longest
//! path in a DAG: each worker-lane `iteration` span defines a window, the
//! window is split at every boundary of a candidate span, and each
//! elementary segment is charged to the most-explanatory covering
//! category (compute beats encode/decode beats remote server/aggregator
//! work beats raw wire wait beats idle). Candidates are the iteration
//! node's own spans plus every remote span whose parent/flow link chain
//! roots in that node, so a shard `apply` that ran on another process
//! lane is charged to the worker iteration that caused it. Because every
//! segment is charged to exactly one hop, the per-hop breakdown sums to
//! the iteration wall time *identically* — if it doesn't, the trace
//! itself is malformed.
//!
//! Output: a per-iteration breakdown, a fleet-level table
//! ([`Report::table`]), a machine-readable JSON report
//! ([`Report::to_json`], what CI parses), and
//! `dynacomm_critical_path_ms{hop=}` gauges holding the mean
//! per-iteration milliseconds charged to each hop — the signal the
//! adaptive control plane (ROADMAP) consumes.

use std::collections::HashMap;

use anyhow::Context;

use crate::obs::Gauge;
use crate::util::json::Json;

/// Hop categories, lowest priority first: a segment covered by several
/// span kinds is charged to the highest-priority cover. Compute outranks
/// everything — while the model is computing, nothing else blocks the
/// iteration; that is the overlap DynaComm exists to create. Remote hops
/// outrank the wire spans that contain them (the uncovered remainder of a
/// `push-seg`/`pull-seg` is genuine wire wait), and `idle` is the
/// uncovered remainder of the window itself.
const HOPS: &[&str] = &[
    "idle",
    "pull-wire",
    "push-wire",
    "agg-fan-out",
    "agg-fan-in",
    "agg-forward",
    "assemble",
    "apply",
    "decode",
    "encode",
    "compute",
];

/// Map a span name from the trace to its hop category (`None`: the span
/// does not participate in attribution — e.g. `iteration` itself).
fn hop_of(span_name: &str) -> Option<usize> {
    let hop = match span_name {
        "fwd-layer" | "loss" | "bwd-layer" => "compute",
        "grad-encode" => "encode",
        "decode-seg" => "decode",
        "apply" => "apply",
        "assemble" => "assemble",
        "agg-forward" => "agg-forward",
        "agg-fan-in" => "agg-fan-in",
        "agg-fan-out" => "agg-fan-out",
        "push-seg" => "push-wire",
        "pull-seg" => "pull-wire",
        _ => return None,
    };
    HOPS.iter().position(|h| *h == hop)
}

#[derive(Debug, Clone)]
struct Span {
    name: String,
    node: String,
    begin_us: f64,
    end_us: f64,
    id: u32,
    parent: u32,
    flow_in: u32,
}

/// One worker iteration's gap-accounted breakdown. `hops` is parallel to
/// [`HOPS`] (microseconds charged); the entries sum to `wall_us` exactly.
#[derive(Debug, Clone)]
pub struct IterBreakdown {
    pub node: String,
    pub begin_us: f64,
    pub wall_us: f64,
    pub hops_us: Vec<f64>,
}

/// Fleet critical-path report. Holding it keeps the
/// `dynacomm_critical_path_ms{hop=}` gauges alive in the registry.
pub struct Report {
    pub iterations: Vec<IterBreakdown>,
    _gauges: Vec<Gauge>,
}

/// Parse a merged Chrome trace and compute the per-iteration critical-path
/// breakdown. Registers/updates the `dynacomm_critical_path_ms` gauges
/// (mean per-iteration milliseconds per hop); drop the report to retire
/// them.
pub fn analyze(trace_json: &str) -> anyhow::Result<Report> {
    let parsed = Json::parse(trace_json)
        .map_err(|e| anyhow::anyhow!("parsing trace JSON: {e}"))?;
    let events = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("trace has no traceEvents array")?;

    // Pass 1: pid -> node name from process_name metadata.
    let mut node_of_pid: HashMap<u64, String> = HashMap::new();
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) == Some("M")
            && e.get("name").and_then(|n| n.as_str()) == Some("process_name")
        {
            if let (Some(pid), Some(name)) = (
                e.get("pid").and_then(|p| p.as_f64()),
                e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()),
            ) {
                node_of_pid.insert(pid as u64, name.to_string());
            }
        }
    }

    // Pass 2: pair B/E per (pid, tid) lane into completed spans. Lanes are
    // well nested by construction of the exporter, so a stack suffices.
    let mut stacks: HashMap<(u64, u64), Vec<Span>> = HashMap::new();
    let mut spans: Vec<Span> = Vec::new();
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph != "B" && ph != "E" {
            continue;
        }
        let pid = e.get("pid").and_then(|p| p.as_f64()).unwrap_or(0.0) as u64;
        let tid = e.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64;
        let ts = e.get("ts").and_then(|t| t.as_f64()).context("event missing ts")?;
        let stack = stacks.entry((pid, tid)).or_default();
        if ph == "B" {
            let arg = |k: &str| {
                e.get("args").and_then(|a| a.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
                    as u32
            };
            stack.push(Span {
                name: e.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                node: node_of_pid.get(&pid).cloned().unwrap_or_else(|| "local".to_string()),
                begin_us: ts,
                end_us: ts,
                id: arg("id"),
                parent: arg("parent"),
                flow_in: arg("flow_in"),
            });
        } else {
            let mut s = stack.pop().with_context(|| {
                format!("unbalanced E event at ts={ts} in lane ({pid},{tid})")
            })?;
            s.end_us = ts;
            spans.push(s);
        }
    }
    anyhow::ensure!(
        stacks.values().all(|s| s.is_empty()),
        "trace has unclosed B events; export only at quiescent points"
    );

    // Link chains: resolve each span to the node its parent/flow chain
    // roots in, so remote work is charged to the iteration that caused it.
    let by_id: HashMap<u32, usize> = spans
        .iter()
        .enumerate()
        .filter(|(_, s)| s.id != 0)
        .map(|(i, s)| (s.id, i))
        .collect();
    let root_node = |mut i: usize| -> String {
        for _ in 0..32 {
            let s = &spans[i];
            let up = if s.parent != 0 { s.parent } else { s.flow_in };
            match by_id.get(&up) {
                Some(&j) if up != 0 => i = j,
                _ => break,
            }
        }
        spans[i].node.clone()
    };
    let owner: Vec<String> = (0..spans.len()).map(root_node).collect();

    // Gap-account every worker-lane iteration window.
    let mut iterations = Vec::new();
    for (i, it) in spans.iter().enumerate() {
        if it.name != "iteration" {
            continue;
        }
        let node = &it.node;
        let (w0, w1) = (it.begin_us, it.end_us);
        // A node's own spans participate regardless of their links — a
        // worker's pull-seg flows *from* the remote assemble that produced
        // the reply, which must not re-own the worker's wire wait to the
        // shard. Remote spans participate when their chain roots here.
        let candidates: Vec<(usize, f64, f64)> = spans
            .iter()
            .enumerate()
            .filter(|(j, s)| {
                *j != i
                    && (&s.node == node || &owner[*j] == node)
                    && s.end_us > w0
                    && s.begin_us < w1
            })
            .filter_map(|(_, s)| {
                hop_of(&s.name).map(|h| (h, s.begin_us.max(w0), s.end_us.min(w1)))
            })
            .collect();
        let mut cuts: Vec<f64> = vec![w0, w1];
        for &(_, b, e) in &candidates {
            cuts.push(b);
            cuts.push(e);
        }
        cuts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        cuts.dedup();
        let mut hops_us = vec![0.0; HOPS.len()];
        for w in cuts.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b <= a {
                continue;
            }
            let mid = (a + b) / 2.0;
            let hop = candidates
                .iter()
                .filter(|&&(_, cb, ce)| cb <= mid && mid < ce)
                .map(|&(h, _, _)| h)
                .max()
                .unwrap_or(0); // uncovered -> idle
            hops_us[hop] += b - a;
        }
        iterations.push(IterBreakdown {
            node: node.clone(),
            begin_us: w0,
            wall_us: w1 - w0,
            hops_us,
        });
    }
    iterations.sort_by(|a, b| {
        a.begin_us
            .partial_cmp(&b.begin_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.node.cmp(&b.node))
    });

    // Summary gauges: mean per-iteration milliseconds charged to each hop.
    let n = iterations.len().max(1) as f64;
    let mut gauges = Vec::with_capacity(HOPS.len());
    let inst = crate::obs::next_inst();
    for (h, hop) in HOPS.iter().enumerate() {
        let total_us: f64 = iterations.iter().map(|it| it.hops_us[h]).sum();
        let g = crate::obs_gauge!(
            "dynacomm_critical_path_ms",
            format!("hop=\"{hop}\""),
            inst
        );
        g.set(total_us / n / 1e3);
        gauges.push(g);
    }

    Ok(Report { iterations, _gauges: gauges })
}

impl Report {
    /// Human-readable per-hop table: total ms charged across iterations,
    /// share of total wall time, and mean ms per iteration.
    pub fn table(&self) -> String {
        let wall_us: f64 = self.iterations.iter().map(|it| it.wall_us).sum();
        let n = self.iterations.len().max(1) as f64;
        let mut out = format!(
            "critical path over {} iteration(s), total wall {:.3} ms\n\
             {:<12} {:>10} {:>8} {:>12}\n",
            self.iterations.len(),
            wall_us / 1e3,
            "hop",
            "total ms",
            "share",
            "mean ms/it"
        );
        for (h, hop) in HOPS.iter().enumerate().rev() {
            let total: f64 = self.iterations.iter().map(|it| it.hops_us[h]).sum();
            if total == 0.0 {
                continue;
            }
            out.push_str(&format!(
                "{:<12} {:>10.3} {:>7.1}% {:>12.3}\n",
                hop,
                total / 1e3,
                100.0 * total / wall_us.max(f64::MIN_POSITIVE),
                total / n / 1e3
            ));
        }
        out
    }

    /// Machine-readable report (what `obs-e2e` CI parses): per-iteration
    /// breakdowns plus per-hop totals, all microseconds.
    pub fn to_json(&self) -> String {
        let iters: Vec<Json> = self
            .iterations
            .iter()
            .map(|it| {
                let hops = Json::Obj(
                    HOPS.iter()
                        .enumerate()
                        .map(|(h, hop)| (hop.to_string(), Json::Num(it.hops_us[h])))
                        .collect(),
                );
                Json::obj(vec![
                    ("node", Json::Str(it.node.clone())),
                    ("begin_us", Json::Num(it.begin_us)),
                    ("wall_us", Json::Num(it.wall_us)),
                    ("hops_us", hops),
                ])
            })
            .collect();
        let totals = Json::Obj(
            HOPS.iter()
                .enumerate()
                .map(|(h, hop)| {
                    let t: f64 = self.iterations.iter().map(|it| it.hops_us[h]).sum();
                    (hop.to_string(), Json::Num(t))
                })
                .collect(),
        );
        Json::obj(vec![
            ("iterations", Json::Arr(iters)),
            ("totals", totals),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal merged-trace JSON: worker-0 lane with one iteration
    /// [0, 1000]us containing compute [0,100]+[400,600] and push-seg
    /// [100,400] (span id 7); shard lane with apply [200,300] whose parent
    /// is the push-seg.
    fn synthetic_trace() -> String {
        let b = |name: &str, ts: f64, pid: u32, tid: u32, id: u32, parent: u32| {
            format!(
                "{{\"name\":\"{name}\",\"cat\":\"dynacomm\",\"ph\":\"B\",\"ts\":{ts},\
                 \"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{\"id\":{id},\"parent\":{parent},\"flow_in\":0}}}}"
            )
        };
        let e = |name: &str, ts: f64, pid: u32, tid: u32| {
            format!(
                "{{\"name\":\"{name}\",\"cat\":\"dynacomm\",\"ph\":\"E\",\"ts\":{ts},\
                 \"pid\":{pid},\"tid\":{tid}}}"
            )
        };
        let events = [
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\
             \"args\":{\"name\":\"shard-9400\"}}"
                .to_string(),
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":2,\
             \"args\":{\"name\":\"worker-0\"}}"
                .to_string(),
            b("iteration", 0.0, 2, 0, 1, 0),
            b("fwd-layer", 0.0, 2, 0, 2, 0),
            e("fwd-layer", 100.0, 2, 0),
            b("push-seg", 100.0, 2, 0, 7, 0),
            e("push-seg", 400.0, 2, 0),
            b("bwd-layer", 400.0, 2, 0, 3, 0),
            e("bwd-layer", 600.0, 2, 0),
            e("iteration", 1000.0, 2, 0),
            b("apply", 200.0, 1, 1, 9, 7),
            e("apply", 300.0, 1, 1),
        ];
        format!("{{\"traceEvents\":[{}]}}", events.join(","))
    }

    #[test]
    fn gap_accounting_sums_to_wall_time_and_charges_remote_hops() {
        let report = analyze(&synthetic_trace()).expect("analyze");
        assert_eq!(report.iterations.len(), 1);
        let it = &report.iterations[0];
        assert_eq!(it.node, "worker-0");
        assert_eq!(it.wall_us, 1000.0);
        let sum: f64 = it.hops_us.iter().sum();
        assert!((sum - it.wall_us).abs() < 1e-6, "breakdown sums exactly: {sum}");
        let hop = |name: &str| it.hops_us[HOPS.iter().position(|h| *h == name).unwrap()];
        // compute [0,100]+[400,600]; push-seg remainder [100,200]+[300,400];
        // shard apply [200,300] charged through its cross-lane parent link;
        // nothing covers [600,1000].
        assert_eq!(hop("compute"), 300.0);
        assert_eq!(hop("push-wire"), 200.0);
        assert_eq!(hop("apply"), 100.0);
        assert_eq!(hop("idle"), 400.0);

        // Both renderings produce consumable output.
        let json = Json::parse(&report.to_json()).expect("report JSON parses");
        let totals = json.get("totals").expect("totals");
        assert_eq!(totals.get("apply").and_then(|v| v.as_f64()), Some(100.0));
        let table = report.table();
        assert!(table.contains("push-wire"), "table lists hops:\n{table}");

        // Summary gauges: mean per-iteration ms per hop.
        let text = crate::obs::render_prometheus();
        assert!(
            text.lines().any(|l| l.starts_with("dynacomm_critical_path_ms{")
                && l.contains("hop=\"apply\"")
                && l.ends_with(" 0.1")),
            "100us apply over one iteration -> 0.1ms:\n{text}"
        );
    }

    #[test]
    fn malformed_traces_are_rejected() {
        assert!(analyze("not json").is_err());
        assert!(analyze("{\"traceEvents\":42}").is_err());
        // Unbalanced B without E.
        let unbalanced = "{\"traceEvents\":[{\"name\":\"iteration\",\"ph\":\"B\",\
                          \"ts\":0,\"pid\":1,\"tid\":0,\"args\":{\"id\":1,\"parent\":0,\
                          \"flow_in\":0}}]}";
        assert!(analyze(unbalanced).is_err());
    }
}
