//! Span tracing: fixed-capacity per-thread ring buffers of completed
//! begin/end events, exportable as Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto) so the comms–compute overlap the
//! scheduler *predicts* is literally visible per iteration. See
//! docs/OBSERVABILITY.md for the span taxonomy.
//!
//! Recording discipline:
//!
//! * Tracing is globally armed via [`set_enabled`]; when off, [`span`]
//!   returns a disarmed guard and costs one relaxed load.
//! * A [`SpanGuard`] stamps its begin time at construction and records the
//!   completed `(name, begin, end)` triple into the calling thread's ring
//!   on drop — only *finished* spans are stored, so exported traces have
//!   balanced B/E pairs by construction.
//! * Rings are keyed by **thread name** and overwrite-oldest at capacity
//!   ([`RING_CAP`]): a later thread with the same name (the worker
//!   respawns `puller-N` / `pusher-N` every iteration) reuses the existing
//!   ring instead of registering a new one, so the global store stays
//!   bounded by the number of distinct thread names over the whole run.
//!   [`Ring::record`] claims slots with an atomic `fetch_add`, so briefly
//!   overlapping same-named writers stay safe; readers tolerate in-flight
//!   overwrites because export happens at quiescent points (end of run /
//!   scrape).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::sync::lock_or_die;

/// Default per-thread ring capacity (spans retained per thread).
pub const RING_CAP: usize = 4096;

/// Span name table: index == the `u32` id passed to [`span`].
pub const SPAN_NAMES: &[&str] = &[
    "iteration",
    "pull-seg",
    "decode-seg",
    "fwd-layer",
    "loss",
    "bwd-layer",
    "grad-encode",
    "push-seg",
    "assemble",
    "apply",
    "agg-fan-in",
    "agg-fan-out",
    "agg-forward",
];

pub const SPAN_ITERATION: u32 = 0;
pub const SPAN_PULL_SEG: u32 = 1;
pub const SPAN_DECODE_SEG: u32 = 2;
pub const SPAN_FWD_LAYER: u32 = 3;
pub const SPAN_LOSS: u32 = 4;
pub const SPAN_BWD_LAYER: u32 = 5;
pub const SPAN_GRAD_ENCODE: u32 = 6;
pub const SPAN_PUSH_SEG: u32 = 7;
pub const SPAN_ASSEMBLE: u32 = 8;
pub const SPAN_APPLY: u32 = 9;
pub const SPAN_AGG_FAN_IN: u32 = 10;
pub const SPAN_AGG_FAN_OUT: u32 = 11;
pub const SPAN_AGG_FORWARD: u32 = 12;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Arm or disarm span recording process-wide (`--trace-out` sets this).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span recording is currently armed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotone nanoseconds since the first observability event in the process.
pub fn now_ns() -> u64 {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

struct SpanSlot {
    /// Span-name id; `u32::MAX` marks a never-written slot.
    name: AtomicU32,
    begin_ns: AtomicU64,
    end_ns: AtomicU64,
}

/// Fixed-capacity overwrite-oldest span ring. Public so tests can exercise
/// the overflow policy directly; production rings are per thread-name,
/// created lazily by [`span`] and shared by successive threads that reuse
/// a name.
pub struct Ring {
    cap: usize,
    head: AtomicUsize,
    slots: Vec<SpanSlot>,
}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        assert!(cap > 0, "span ring capacity must be positive");
        Ring {
            cap,
            head: AtomicUsize::new(0),
            slots: (0..cap)
                .map(|_| SpanSlot {
                    name: AtomicU32::new(u32::MAX),
                    begin_ns: AtomicU64::new(0),
                    end_ns: AtomicU64::new(0),
                })
                .collect(),
        }
    }

    /// Record one completed span, overwriting the oldest entry at capacity.
    pub fn record(&self, name: u32, begin_ns: u64, end_ns: u64) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed) % self.cap;
        let slot = &self.slots[idx];
        slot.begin_ns.store(begin_ns, Ordering::Relaxed);
        slot.end_ns.store(end_ns, Ordering::Relaxed);
        slot.name.store(name, Ordering::Relaxed);
    }

    /// Retained spans, oldest first: `(name, begin_ns, end_ns)`.
    pub fn snapshot(&self) -> Vec<(u32, u64, u64)> {
        let head = self.head.load(Ordering::Relaxed);
        let n = head.min(self.cap);
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let idx = if head <= self.cap { k } else { (head + k) % self.cap };
            let slot = &self.slots[idx];
            let name = slot.name.load(Ordering::Relaxed);
            if name == u32::MAX {
                continue;
            }
            out.push((
                name,
                slot.begin_ns.load(Ordering::Relaxed),
                slot.end_ns.load(Ordering::Relaxed),
            ));
        }
        out
    }
}

fn rings_store() -> &'static Mutex<Vec<(String, Arc<Ring>)>> {
    static RINGS: OnceLock<Mutex<Vec<(String, Arc<Ring>)>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL_RING: Arc<Ring> = register_thread_ring();
}

/// Find-or-create the ring for the calling thread's name. Reuse keeps the
/// store (and trace export) bounded when same-named threads are respawned
/// every iteration — the puller/pusher pattern — instead of leaking one
/// ~`RING_CAP`-slot ring per spawn for the lifetime of the process.
fn register_thread_ring() -> Arc<Ring> {
    let thread = std::thread::current();
    let name = thread.name().unwrap_or("unnamed");
    let mut rings = lock_or_die(rings_store(), "obs.rings");
    if let Some((_, ring)) = rings.iter().find(|(n, _)| n == name) {
        return ring.clone();
    }
    let ring = Arc::new(Ring::new(RING_CAP));
    rings.push((name.to_string(), ring.clone()));
    ring
}

/// RAII span: stamps begin at construction, records `(name, begin, end)`
/// into the calling thread's ring on drop. Disarmed (free) when tracing is
/// off. The first span under a given thread *name* registers that name's
/// ring (one allocation); later spans — including ones on freshly spawned
/// threads reusing the name — find it by lookup, so steady state allocates
/// nothing even when worker threads are respawned per iteration.
pub struct SpanGuard {
    name: u32,
    begin_ns: u64,
    armed: bool,
}

/// Open a span for `name` (one of the `SPAN_*` ids).
pub fn span(name: u32) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { name, begin_ns: 0, armed: false };
    }
    SpanGuard { name, begin_ns: now_ns(), armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = now_ns();
        // try_with: a guard dropped during thread teardown (TLS already
        // destroyed) silently loses its span instead of aborting.
        let _ = LOCAL_RING.try_with(|r| r.record(self.name, self.begin_ns, end));
    }
}

struct TraceEvent {
    ts_us: f64,
    /// 0 = end, 1 = begin: at equal timestamps close the previous span
    /// before opening the next so the per-tid stack stays well nested.
    phase: u8,
    /// Tie-break between same-phase events at one timestamp: begins open
    /// longest-first (outermost first), ends close shortest-first.
    dur_ns: u64,
    name: u32,
}

/// Escape a string for embedding inside a JSON string literal. Thread
/// names come from arbitrary `std::thread` builders, so quotes,
/// backslashes, and control characters must not reach the trace verbatim.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Export every thread's retained spans as Chrome trace-event JSON
/// (`{"traceEvents": [...]}` with `B`/`E` duration events plus
/// `thread_name` metadata). Timestamps are microseconds.
pub fn chrome_trace_json() -> String {
    let rings = lock_or_die(rings_store(), "obs.rings");
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for (tid, (tname, ring)) in rings.iter().enumerate() {
        let spans = ring.snapshot();
        if spans.is_empty() {
            continue;
        }
        let mut events = Vec::with_capacity(spans.len() * 2);
        for (name, begin, end) in spans {
            let dur = end.saturating_sub(begin);
            events.push(TraceEvent { ts_us: begin as f64 / 1e3, phase: 1, dur_ns: dur, name });
            events.push(TraceEvent { ts_us: end as f64 / 1e3, phase: 0, dur_ns: dur, name });
        }
        events.sort_by(|a, b| {
            a.ts_us
                .partial_cmp(&b.ts_us)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.phase.cmp(&b.phase))
                .then(if a.phase == 1 {
                    b.dur_ns.cmp(&a.dur_ns) // begins: longest (outermost) first
                } else {
                    a.dur_ns.cmp(&b.dur_ns) // ends: shortest (innermost) first
                })
        });
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(tname)
        ));
        for e in events {
            let ph = if e.phase == 1 { "B" } else { "E" };
            let sname = SPAN_NAMES
                .get(e.name as usize)
                .copied()
                .unwrap_or("unknown");
            out.push_str(&format!(
                ",{{\"name\":\"{sname}\",\"cat\":\"dynacomm\",\"ph\":\"{ph}\",\
                 \"ts\":{:.3},\"pid\":1,\"tid\":{tid}}}",
                e.ts_us
            ));
        }
    }
    out.push_str("]}");
    out
}

/// Write the Chrome trace for the whole process to `path` (`--trace-out`).
pub fn write_chrome_trace(path: &str) -> anyhow::Result<()> {
    use anyhow::Context;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace_json())
        .with_context(|| format!("writing chrome trace to {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_drops_oldest() {
        let r = Ring::new(4);
        for i in 0..7u32 {
            r.record(i, i as u64 * 10, i as u64 * 10 + 5);
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        // Oldest three (0, 1, 2) dropped; survivors in oldest-first order.
        let names: Vec<u32> = snap.iter().map(|s| s.0).collect();
        assert_eq!(names, vec![3, 4, 5, 6]);
        assert_eq!(snap[0].1, 30);
        assert_eq!(snap[3].2, 65);
    }

    #[test]
    fn ring_under_capacity_keeps_everything_in_order() {
        let r = Ring::new(8);
        r.record(2, 1, 2);
        r.record(3, 3, 4);
        assert_eq!(r.snapshot(), vec![(2, 1, 2), (3, 3, 4)]);
    }

    // Single test for everything that toggles the process-global ENABLED
    // flag: separate #[test]s would race each other under the parallel
    // test harness.
    #[test]
    fn span_recording_and_chrome_export() {
        // Disarmed: a guard neither registers a ring nor records a span.
        set_enabled(false);
        std::thread::Builder::new()
            .name("obs-test-disarmed".into())
            .spawn(|| {
                let _g = span(SPAN_LOSS);
            })
            .unwrap()
            .join()
            .unwrap();
        assert!(
            !lock_or_die(rings_store(), "obs.rings")
                .iter()
                .any(|(n, _)| n == "obs-test-disarmed"),
            "disarmed span must not register a thread ring"
        );

        // Armed: spans land in the recording thread's ring, completed.
        set_enabled(true);
        std::thread::Builder::new()
            .name("obs-test-armed".into())
            .spawn(|| {
                let _outer = span(SPAN_ITERATION);
                for _ in 0..3 {
                    let _inner = span(SPAN_FWD_LAYER);
                }
            })
            .unwrap()
            .join()
            .unwrap();

        // Respawned same-named threads reuse one ring instead of leaking a
        // new registration per spawn (the per-iteration puller/pusher
        // pattern); their spans accumulate in the shared ring.
        for _ in 0..3 {
            std::thread::Builder::new()
                .name("obs-test-reused".into())
                .spawn(|| {
                    let _g = span(SPAN_PUSH_SEG);
                })
                .unwrap()
                .join()
                .unwrap();
        }
        {
            let rings = lock_or_die(rings_store(), "obs.rings");
            let reused: Vec<_> =
                rings.iter().filter(|(n, _)| n == "obs-test-reused").collect();
            assert_eq!(reused.len(), 1, "same-named respawns must share one ring");
            assert_eq!(reused[0].1.snapshot().len(), 3, "all spawns' spans retained");
        }

        // A hostile thread name must not break the JSON export below.
        std::thread::Builder::new()
            .name("obs-test \"quoted\\name".into())
            .spawn(|| {
                let _g = span(SPAN_APPLY);
            })
            .unwrap()
            .join()
            .unwrap();
        set_enabled(false);
        {
            let rings = lock_or_die(rings_store(), "obs.rings");
            let (_, ring) = rings
                .iter()
                .find(|(n, _)| n == "obs-test-armed")
                .expect("armed thread ring registered");
            let snap = ring.snapshot();
            assert_eq!(snap.len(), 4, "outer + 3 inner spans");
            assert!(snap.iter().all(|s| s.2 >= s.1), "end >= begin");
        }

        // Export: valid JSON, balanced B/E pairs.
        let json = chrome_trace_json();
        let parsed = crate::util::json::Json::parse(&json).expect("valid JSON");
        let events = parsed
            .get("traceEvents")
            .and_then(|e| e.as_arr())
            .expect("traceEvents array");
        let mut begins = 0usize;
        let mut ends = 0usize;
        for e in events {
            match e.get("ph").and_then(|p| p.as_str()) {
                Some("B") => begins += 1,
                Some("E") => ends += 1,
                _ => {}
            }
        }
        assert!(begins >= 4, "expected at least the 4 test spans, got {begins}");
        assert_eq!(begins, ends, "balanced B/E pairs");
    }
}
