//! Span tracing: fixed-capacity per-thread ring buffers of completed
//! begin/end events, exportable as Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto) so the comms–compute overlap the
//! scheduler *predicts* is literally visible per iteration. See
//! docs/OBSERVABILITY.md for the span taxonomy.
//!
//! Recording discipline:
//!
//! * Tracing is globally armed via [`set_enabled`]; when off, [`span`]
//!   returns a disarmed guard and costs one relaxed load.
//! * A [`SpanGuard`] stamps its begin time at construction and records the
//!   completed `(name, begin, end)` triple into the calling thread's ring
//!   on drop — only *finished* spans are stored, so exported traces have
//!   balanced B/E pairs by construction.
//! * Rings are keyed by **thread name** and overwrite-oldest at capacity
//!   ([`RING_CAP`]): a later thread with the same name (the worker
//!   respawns `puller-N` / `pusher-N` every iteration) reuses the existing
//!   ring instead of registering a new one, so the global store stays
//!   bounded by the number of distinct thread names over the whole run.
//!   [`Ring::record`] claims slots with an atomic `fetch_add`, so briefly
//!   overlapping same-named writers stay safe; readers tolerate in-flight
//!   overwrites because export happens at quiescent points (end of run /
//!   scrape).
//!
//! Fleet tracing (wire v7) extends the model with three per-span links:
//! every armed span draws a process-unique **span id**, and a receiver
//! that decodes a [`crate::net::TraceCtx`] stores the sender's span id as
//! either a **remote parent** (request direction — the child nests inside
//! the parent's window) or a **flow source** (reply direction — an arrow
//! without containment). Threads adopt a **node** label
//! ([`adopt_node`]: `worker-0`, `agg-1`, `shard-9400`), which becomes a
//! per-node process lane in the merged Chrome trace; export corrects each
//! lane's timestamps by the clock offset [`crate::obs::clock`] measured
//! for that node and stitches cross-lane links as flow (`s`/`f`) arrows.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::sync::lock_or_die;

/// Default per-thread ring capacity (spans retained per thread).
pub const RING_CAP: usize = 4096;

/// Span name table: index == the `u32` id passed to [`span`].
pub const SPAN_NAMES: &[&str] = &[
    "iteration",
    "pull-seg",
    "decode-seg",
    "fwd-layer",
    "loss",
    "bwd-layer",
    "grad-encode",
    "push-seg",
    "assemble",
    "apply",
    "agg-fan-in",
    "agg-fan-out",
    "agg-forward",
];

pub const SPAN_ITERATION: u32 = 0;
pub const SPAN_PULL_SEG: u32 = 1;
pub const SPAN_DECODE_SEG: u32 = 2;
pub const SPAN_FWD_LAYER: u32 = 3;
pub const SPAN_LOSS: u32 = 4;
pub const SPAN_BWD_LAYER: u32 = 5;
pub const SPAN_GRAD_ENCODE: u32 = 6;
pub const SPAN_PUSH_SEG: u32 = 7;
pub const SPAN_ASSEMBLE: u32 = 8;
pub const SPAN_APPLY: u32 = 9;
pub const SPAN_AGG_FAN_IN: u32 = 10;
pub const SPAN_AGG_FAN_OUT: u32 = 11;
pub const SPAN_AGG_FORWARD: u32 = 12;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Arm or disarm span recording process-wide (`--trace-out` sets this).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span recording is currently armed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotone nanoseconds since the first observability event in the
/// process, plus the calling thread's injected clock skew (zero outside
/// tests — [`set_node_skew_ns`]). The skew knob is what makes the offset
/// probe and the export-time correction testable in a single process,
/// where every thread otherwise shares one physical clock.
pub fn now_ns() -> u64 {
    static START: OnceLock<Instant> = OnceLock::new();
    let base = START.get_or_init(Instant::now).elapsed().as_nanos() as i64;
    let skew = THREAD_SKEW_NS.with(|s| s.get());
    (base + skew).max(0) as u64
}

/// Monotonically increasing process-unique span ids; 0 means "no span",
/// so the counter starts at 1.
static NEXT_SPAN_ID: AtomicU32 = AtomicU32::new(1);

fn next_span_id() -> u32 {
    NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed)
}

/// The run seed mixed into every trace id ([`trace_id_for`]); the trainer
/// sets it once at startup so concurrent runs' traces never collide.
static RUN_SEED: AtomicU64 = AtomicU64::new(0);

pub fn set_run_seed(seed: u64) {
    RUN_SEED.store(seed, Ordering::Relaxed);
}

/// The fleet-wide trace id of one logical iteration: an FNV-1a hash of
/// the run seed and the iteration number, carried on every v7 trace
/// context that iteration's frames emit.
pub fn trace_id_for(iter: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in RUN_SEED
        .load(Ordering::Relaxed)
        .to_le_bytes()
        .into_iter()
        .chain(iter.to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

thread_local! {
    /// The node lane this thread records into ("" until [`adopt_node`]).
    static THREAD_NODE: RefCell<String> = RefCell::new(String::new());
    /// Injected clock skew for this thread's [`now_ns`] reads.
    static THREAD_SKEW_NS: Cell<i64> = Cell::new(0);
}

/// Per-node injected clock skews ([`set_node_skew_ns`]), applied to a
/// thread when it adopts the node.
fn skew_store() -> &'static Mutex<Vec<(String, i64)>> {
    static SKEWS: OnceLock<Mutex<Vec<(String, i64)>>> = OnceLock::new();
    SKEWS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Inject a clock skew for every thread that adopts `node` from now on
/// (test knob: a single-process fleet has no real skew to measure, so the
/// e2e injects one and asserts the probe finds it and export removes it).
pub fn set_node_skew_ns(node: &str, skew_ns: i64) {
    let mut skews = lock_or_die(skew_store(), "obs.skews");
    if let Some(entry) = skews.iter_mut().find(|(n, _)| n == node) {
        entry.1 = skew_ns;
    } else {
        skews.push((node.to_string(), skew_ns));
    }
}

fn node_skew_ns(node: &str) -> i64 {
    lock_or_die(skew_store(), "obs.skews")
        .iter()
        .find(|(n, _)| n == node)
        .map(|(_, s)| *s)
        .unwrap_or(0)
}

/// Label the calling thread as part of `node` (e.g. `worker-0`,
/// `agg-1`, `shard-9400`): its ring is grouped into that node's process
/// lane in the merged trace, and any injected skew for the node starts
/// applying to this thread's clock reads. Cold path — called once per
/// thread spawn, before its first span.
pub fn adopt_node(node: &str) {
    THREAD_SKEW_NS.with(|s| s.set(node_skew_ns(node)));
    THREAD_NODE.with(|n| *n.borrow_mut() = node.to_string());
    // Force ring registration under the adopted node, or re-label an
    // already-registered ring (same-named respawns adopt before spanning).
    let thread = std::thread::current();
    let name = thread.name().unwrap_or("unnamed").to_string();
    let mut rings = lock_or_die(rings_store(), "obs.rings");
    if let Some(entry) = rings.iter_mut().find(|e| e.thread == name) {
        entry.node = node.to_string();
    } else {
        rings.push(RingEntry {
            thread: name,
            node: node.to_string(),
            ring: Arc::new(Ring::new(RING_CAP)),
        });
    }
}

struct SpanSlot {
    /// Span-name id; `u32::MAX` marks a never-written slot.
    name: AtomicU32,
    begin_ns: AtomicU64,
    end_ns: AtomicU64,
    /// Process-unique span id (0 for spans recorded without one).
    id: AtomicU32,
    /// Remote parent span id (0 = none): containment link — this span
    /// nests inside the parent's window.
    parent: AtomicU32,
    /// Flow-source span id (0 = none): arrow-only link, no containment
    /// claim (reply-direction stitches).
    flow_in: AtomicU32,
}

/// One retained span with its fleet-tracing links, as returned by
/// [`Ring::snapshot_linked`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRec {
    pub name: u32,
    pub begin_ns: u64,
    pub end_ns: u64,
    pub id: u32,
    pub parent: u32,
    pub flow_in: u32,
}

/// Fixed-capacity overwrite-oldest span ring. Public so tests can exercise
/// the overflow policy directly; production rings are per thread-name,
/// created lazily by [`span`] and shared by successive threads that reuse
/// a name.
pub struct Ring {
    cap: usize,
    head: AtomicUsize,
    slots: Vec<SpanSlot>,
}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        assert!(cap > 0, "span ring capacity must be positive");
        Ring {
            cap,
            head: AtomicUsize::new(0),
            slots: (0..cap)
                .map(|_| SpanSlot {
                    name: AtomicU32::new(u32::MAX),
                    begin_ns: AtomicU64::new(0),
                    end_ns: AtomicU64::new(0),
                    id: AtomicU32::new(0),
                    parent: AtomicU32::new(0),
                    flow_in: AtomicU32::new(0),
                })
                .collect(),
        }
    }

    /// Record one completed span, overwriting the oldest entry at capacity.
    pub fn record(&self, name: u32, begin_ns: u64, end_ns: u64) {
        self.record_linked(name, begin_ns, end_ns, 0, 0, 0);
    }

    /// [`Ring::record`] with the fleet-tracing links: the span's own id
    /// plus its remote-parent and flow-source span ids (0 = none each).
    pub fn record_linked(
        &self,
        name: u32,
        begin_ns: u64,
        end_ns: u64,
        id: u32,
        parent: u32,
        flow_in: u32,
    ) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed) % self.cap;
        let slot = &self.slots[idx];
        slot.begin_ns.store(begin_ns, Ordering::Relaxed);
        slot.end_ns.store(end_ns, Ordering::Relaxed);
        slot.id.store(id, Ordering::Relaxed);
        slot.parent.store(parent, Ordering::Relaxed);
        slot.flow_in.store(flow_in, Ordering::Relaxed);
        slot.name.store(name, Ordering::Relaxed);
    }

    /// Retained spans, oldest first: `(name, begin_ns, end_ns)`.
    pub fn snapshot(&self) -> Vec<(u32, u64, u64)> {
        self.snapshot_linked()
            .into_iter()
            .map(|s| (s.name, s.begin_ns, s.end_ns))
            .collect()
    }

    /// Retained spans with their fleet-tracing links, oldest first.
    pub fn snapshot_linked(&self) -> Vec<SpanRec> {
        let head = self.head.load(Ordering::Relaxed);
        let n = head.min(self.cap);
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let idx = if head <= self.cap { k } else { (head + k) % self.cap };
            let slot = &self.slots[idx];
            let name = slot.name.load(Ordering::Relaxed);
            if name == u32::MAX {
                continue;
            }
            out.push(SpanRec {
                name,
                begin_ns: slot.begin_ns.load(Ordering::Relaxed),
                end_ns: slot.end_ns.load(Ordering::Relaxed),
                id: slot.id.load(Ordering::Relaxed),
                parent: slot.parent.load(Ordering::Relaxed),
                flow_in: slot.flow_in.load(Ordering::Relaxed),
            });
        }
        out
    }
}

/// One registered thread ring: the thread name that keys it, the node
/// lane it exports under ("" until [`adopt_node`]), and the ring itself.
struct RingEntry {
    thread: String,
    node: String,
    ring: Arc<Ring>,
}

fn rings_store() -> &'static Mutex<Vec<RingEntry>> {
    static RINGS: OnceLock<Mutex<Vec<RingEntry>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL_RING: Arc<Ring> = register_thread_ring();
}

/// Find-or-create the ring for the calling thread's name. Reuse keeps the
/// store (and trace export) bounded when same-named threads are respawned
/// every iteration — the puller/pusher pattern — instead of leaking one
/// ~`RING_CAP`-slot ring per spawn for the lifetime of the process.
fn register_thread_ring() -> Arc<Ring> {
    let thread = std::thread::current();
    let name = thread.name().unwrap_or("unnamed");
    let node = THREAD_NODE.with(|n| n.borrow().clone());
    let mut rings = lock_or_die(rings_store(), "obs.rings");
    if let Some(entry) = rings.iter_mut().find(|e| e.thread == name) {
        if entry.node.is_empty() && !node.is_empty() {
            entry.node = node;
        }
        return entry.ring.clone();
    }
    let ring = Arc::new(Ring::new(RING_CAP));
    rings.push(RingEntry { thread: name.to_string(), node, ring: ring.clone() });
    ring
}

/// RAII span: stamps begin at construction, records `(name, begin, end)`
/// into the calling thread's ring on drop. Disarmed (free) when tracing is
/// off. The first span under a given thread *name* registers that name's
/// ring (one allocation); later spans — including ones on freshly spawned
/// threads reusing the name — find it by lookup, so steady state allocates
/// nothing even when worker threads are respawned per iteration.
pub struct SpanGuard {
    name: u32,
    begin_ns: u64,
    armed: bool,
    id: u32,
    parent: u32,
    flow_in: u32,
}

/// Open a span for `name` (one of the `SPAN_*` ids). Armed spans draw a
/// process-unique id — the value a v7 trace context carries to the peer.
pub fn span(name: u32) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { name, begin_ns: 0, armed: false, id: 0, parent: 0, flow_in: 0 };
    }
    SpanGuard {
        name,
        begin_ns: now_ns(),
        armed: true,
        id: next_span_id(),
        parent: 0,
        flow_in: 0,
    }
}

impl SpanGuard {
    /// This span's process-unique id (0 when tracing is disarmed) — what
    /// a sender puts in the [`crate::net::TraceCtx`] it emits under this
    /// span.
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Adopt a remote sender's span id as this span's parent (request
    /// direction: this span runs inside the sender's window — a worker's
    /// push-seg contains the aggregator's fan-in contains the shard's
    /// apply).
    pub fn set_remote_parent(&mut self, span_id: u32) {
        self.parent = span_id;
    }

    /// Record an arrow-only stitch from a remote span (reply direction:
    /// the server's assemble caused this decode, but the windows do not
    /// nest).
    pub fn set_flow_from(&mut self, span_id: u32) {
        self.flow_in = span_id;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = now_ns();
        // try_with: a guard dropped during thread teardown (TLS already
        // destroyed) silently loses its span instead of aborting.
        let _ = LOCAL_RING.try_with(|r| {
            r.record_linked(self.name, self.begin_ns, end, self.id, self.parent, self.flow_in)
        });
    }
}

struct TraceEvent {
    ts_us: f64,
    /// 0 = end, 1 = begin: at equal timestamps close the previous span
    /// before opening the next so the per-tid stack stays well nested.
    phase: u8,
    /// Tie-break between same-phase events at one timestamp: begins open
    /// longest-first (outermost first), ends close shortest-first.
    dur_ns: u64,
    name: u32,
    id: u32,
    parent: u32,
    flow_in: u32,
}

/// Escape a string for embedding inside a JSON string literal. Thread
/// names come from arbitrary `std::thread` builders, so quotes,
/// backslashes, and control characters must not reach the trace verbatim.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Export every thread's retained spans as ONE merged Chrome trace
/// (`{"traceEvents": [...]}`): per-node **process lanes** (pid per node,
/// `process_name` metadata; threads that never adopted a node export under
/// the `local` lane), `B`/`E` duration events whose timestamps are
/// **offset-corrected** by the node's measured clock offset
/// ([`crate::obs::clock::node_offset_ns`]), span-link `args`
/// (`id`/`parent`/`flow_in`) on `B` events, and flow (`s`/`f`) arrows
/// stitching every cross-process link whose source span is present.
/// Timestamps are microseconds.
pub fn chrome_trace_json() -> String {
    use std::collections::HashMap;
    struct Lane {
        thread: String,
        node: String,
        offset_ns: i64,
        spans: Vec<SpanRec>,
    }
    // Snapshot under the lock, render outside it.
    let lanes: Vec<Lane> = {
        let rings = lock_or_die(rings_store(), "obs.rings");
        rings
            .iter()
            .filter_map(|e| {
                let spans = e.ring.snapshot_linked();
                if spans.is_empty() {
                    return None;
                }
                let node =
                    if e.node.is_empty() { "local".to_string() } else { e.node.clone() };
                let offset_ns = crate::obs::clock::node_offset_ns(&node);
                Some(Lane { thread: e.thread.clone(), node, offset_ns, spans })
            })
            .collect()
    };
    // One pid per node, assigned in sorted order so lane layout is stable
    // across runs regardless of thread registration order.
    let mut nodes: Vec<String> = lanes.iter().map(|l| l.node.clone()).collect();
    nodes.sort();
    nodes.dedup();
    let pid_of = |node: &str| nodes.iter().position(|n| n == node).unwrap_or(0) + 1;
    // Where every span id lives, for flow-arrow endpoints: id -> (pid,
    // tid, corrected begin us).
    let mut at: HashMap<u32, (usize, usize, f64)> = HashMap::new();
    for (tid, lane) in lanes.iter().enumerate() {
        let pid = pid_of(&lane.node);
        for s in &lane.spans {
            if s.id != 0 {
                at.insert(s.id, (pid, tid, (s.begin_ns as i64 - lane.offset_ns) as f64 / 1e3));
            }
        }
    }
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for node in &nodes {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            pid_of(node),
            json_escape(node)
        ));
    }
    for (tid, lane) in lanes.iter().enumerate() {
        let pid = pid_of(&lane.node);
        let mut events = Vec::with_capacity(lane.spans.len() * 2);
        for s in &lane.spans {
            let begin = s.begin_ns as i64 - lane.offset_ns;
            let end = s.end_ns as i64 - lane.offset_ns;
            let dur = (end - begin).max(0) as u64;
            events.push(TraceEvent {
                ts_us: begin as f64 / 1e3,
                phase: 1,
                dur_ns: dur,
                name: s.name,
                id: s.id,
                parent: s.parent,
                flow_in: s.flow_in,
            });
            events.push(TraceEvent {
                ts_us: end as f64 / 1e3,
                phase: 0,
                dur_ns: dur,
                name: s.name,
                id: s.id,
                parent: s.parent,
                flow_in: s.flow_in,
            });
        }
        events.sort_by(|a, b| {
            a.ts_us
                .partial_cmp(&b.ts_us)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.phase.cmp(&b.phase))
                .then(if a.phase == 1 {
                    b.dur_ns.cmp(&a.dur_ns) // begins: longest (outermost) first
                } else {
                    a.dur_ns.cmp(&b.dur_ns) // ends: shortest (innermost) first
                })
        });
        out.push_str(&format!(
            ",{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(&lane.thread)
        ));
        for e in events {
            let sname = SPAN_NAMES
                .get(e.name as usize)
                .copied()
                .unwrap_or("unknown");
            if e.phase == 1 {
                out.push_str(&format!(
                    ",{{\"name\":\"{sname}\",\"cat\":\"dynacomm\",\"ph\":\"B\",\
                     \"ts\":{:.3},\"pid\":{pid},\"tid\":{tid},\
                     \"args\":{{\"id\":{},\"parent\":{},\"flow_in\":{}}}}}",
                    e.ts_us, e.id, e.parent, e.flow_in
                ));
            } else {
                out.push_str(&format!(
                    ",{{\"name\":\"{sname}\",\"cat\":\"dynacomm\",\"ph\":\"E\",\
                     \"ts\":{:.3},\"pid\":{pid},\"tid\":{tid}}}",
                    e.ts_us
                ));
            }
        }
        // Flow arrows: one s/f pair per resolvable link. Arrow ids must be
        // unique per arrow, and a span can carry both a parent and a
        // flow_in link, so the id is the child span id with a kind bit.
        for s in &lane.spans {
            let child_ts = (s.begin_ns as i64 - lane.offset_ns) as f64 / 1e3;
            for (kind, src) in [(0u64, s.parent), (1u64, s.flow_in)] {
                if src == 0 {
                    continue;
                }
                let Some(&(spid, stid, sts)) = at.get(&src) else { continue };
                let arrow = (s.id as u64) << 1 | kind;
                out.push_str(&format!(
                    ",{{\"name\":\"ctx\",\"cat\":\"dynacomm\",\"ph\":\"s\",\
                     \"id\":{arrow},\"ts\":{sts:.3},\"pid\":{spid},\"tid\":{stid}}}"
                ));
                out.push_str(&format!(
                    ",{{\"name\":\"ctx\",\"cat\":\"dynacomm\",\"ph\":\"f\",\"bp\":\"e\",\
                     \"id\":{arrow},\"ts\":{child_ts:.3},\"pid\":{pid},\"tid\":{tid}}}"
                ));
            }
        }
    }
    out.push_str("]}");
    out
}

/// Write the Chrome trace for the whole process to `path` (`--trace-out`).
pub fn write_chrome_trace(path: &str) -> anyhow::Result<()> {
    use anyhow::Context;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace_json())
        .with_context(|| format!("writing chrome trace to {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_drops_oldest() {
        let r = Ring::new(4);
        for i in 0..7u32 {
            r.record(i, i as u64 * 10, i as u64 * 10 + 5);
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        // Oldest three (0, 1, 2) dropped; survivors in oldest-first order.
        let names: Vec<u32> = snap.iter().map(|s| s.0).collect();
        assert_eq!(names, vec![3, 4, 5, 6]);
        assert_eq!(snap[0].1, 30);
        assert_eq!(snap[3].2, 65);
    }

    #[test]
    fn ring_under_capacity_keeps_everything_in_order() {
        let r = Ring::new(8);
        r.record(2, 1, 2);
        r.record(3, 3, 4);
        assert_eq!(r.snapshot(), vec![(2, 1, 2), (3, 3, 4)]);
    }

    // Single test for everything that toggles the process-global ENABLED
    // flag: separate #[test]s would race each other under the parallel
    // test harness.
    #[test]
    fn span_recording_and_chrome_export() {
        // Disarmed: a guard neither registers a ring nor records a span.
        set_enabled(false);
        std::thread::Builder::new()
            .name("obs-test-disarmed".into())
            .spawn(|| {
                let _g = span(SPAN_LOSS);
            })
            .unwrap()
            .join()
            .unwrap();
        assert!(
            !lock_or_die(rings_store(), "obs.rings")
                .iter()
                .any(|e| e.thread == "obs-test-disarmed"),
            "disarmed span must not register a thread ring"
        );

        // Armed: spans land in the recording thread's ring, completed.
        set_enabled(true);
        std::thread::Builder::new()
            .name("obs-test-armed".into())
            .spawn(|| {
                let _outer = span(SPAN_ITERATION);
                for _ in 0..3 {
                    let _inner = span(SPAN_FWD_LAYER);
                }
            })
            .unwrap()
            .join()
            .unwrap();

        // Respawned same-named threads reuse one ring instead of leaking a
        // new registration per spawn (the per-iteration puller/pusher
        // pattern); their spans accumulate in the shared ring.
        for _ in 0..3 {
            std::thread::Builder::new()
                .name("obs-test-reused".into())
                .spawn(|| {
                    let _g = span(SPAN_PUSH_SEG);
                })
                .unwrap()
                .join()
                .unwrap();
        }
        {
            let rings = lock_or_die(rings_store(), "obs.rings");
            let reused: Vec<_> =
                rings.iter().filter(|e| e.thread == "obs-test-reused").collect();
            assert_eq!(reused.len(), 1, "same-named respawns must share one ring");
            assert_eq!(reused[0].ring.snapshot().len(), 3, "all spawns' spans retained");
        }

        // Fleet links: a thread that adopts a node records spans with
        // process-unique ids and remote links, its clock readings shift by
        // the node's injected skew, and its ring carries the node label.
        set_node_skew_ns("obs-test-node", 5_000_000);
        let before_ns = now_ns();
        let skewed_ns = std::thread::Builder::new()
            .name("obs-test-linked".into())
            .spawn(|| {
                adopt_node("obs-test-node");
                let parent = span(SPAN_PUSH_SEG);
                let parent_id = parent.id();
                assert_ne!(parent_id, 0, "armed spans draw a nonzero id");
                drop(parent);
                let mut child = span(SPAN_APPLY);
                assert!(child.id() > parent_id, "span ids increase monotonically");
                child.set_remote_parent(parent_id);
                drop(child);
                let mut decode = span(SPAN_DECODE_SEG);
                decode.set_flow_from(parent_id);
                drop(decode);
                now_ns()
            })
            .unwrap()
            .join()
            .unwrap();
        assert!(
            skewed_ns >= before_ns + 4_000_000,
            "injected +5ms skew must surface in the adopting thread's clock \
             ({skewed_ns} vs {before_ns})"
        );
        let (raw_push_begin, push_id) = {
            let rings = lock_or_die(rings_store(), "obs.rings");
            let entry = rings
                .iter()
                .find(|e| e.thread == "obs-test-linked")
                .expect("linked thread ring registered");
            assert_eq!(entry.node, "obs-test-node", "adopt_node labels the ring");
            let snap = entry.ring.snapshot_linked();
            assert_eq!(snap.len(), 3);
            assert_eq!(snap[1].parent, snap[0].id, "remote parent recorded");
            assert_eq!(snap[2].flow_in, snap[0].id, "flow source recorded");
            assert_eq!(snap[0].parent, 0);
            assert_eq!(snap[0].flow_in, 0);
            (snap[0].begin_ns, snap[0].id)
        };

        // A hostile thread name must not break the JSON export below.
        std::thread::Builder::new()
            .name("obs-test \"quoted\\name".into())
            .spawn(|| {
                let _g = span(SPAN_APPLY);
            })
            .unwrap()
            .join()
            .unwrap();
        set_enabled(false);
        {
            let rings = lock_or_die(rings_store(), "obs.rings");
            let entry = rings
                .iter()
                .find(|e| e.thread == "obs-test-armed")
                .expect("armed thread ring registered");
            let snap = entry.ring.snapshot();
            assert_eq!(snap.len(), 4, "outer + 3 inner spans");
            assert!(snap.iter().all(|s| s.2 >= s.1), "end >= begin");
        }

        // Export: valid JSON, balanced B/E pairs, per-node process lanes,
        // offset-corrected timestamps, flow arrows for both link kinds.
        crate::obs::clock::note_node_offset("obs-test-node", 5_000_000, 50_000);
        let json = chrome_trace_json();
        let parsed = crate::util::json::Json::parse(&json).expect("valid JSON");
        let events = parsed
            .get("traceEvents")
            .and_then(|e| e.as_arr())
            .expect("traceEvents array");
        let mut begins = 0usize;
        let mut ends = 0usize;
        let mut flow_s = 0usize;
        let mut flow_f = 0usize;
        let mut node_pid = None;
        for e in events {
            match e.get("ph").and_then(|p| p.as_str()) {
                Some("B") => begins += 1,
                Some("E") => ends += 1,
                Some("s") => flow_s += 1,
                Some("f") => flow_f += 1,
                Some("M") => {
                    if e.get("name").and_then(|n| n.as_str()) == Some("process_name")
                        && e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str())
                            == Some("obs-test-node")
                    {
                        node_pid = e.get("pid").and_then(|p| p.as_f64());
                    }
                }
                _ => {}
            }
        }
        assert!(begins >= 4, "expected at least the 4 test spans, got {begins}");
        assert_eq!(begins, ends, "balanced B/E pairs");
        assert!(flow_s >= 2 && flow_s == flow_f, "parent + flow_in arrows stitched");
        let node_pid = node_pid.expect("adopted node gets its own process lane");
        // The push-seg B event in the node lane is offset-corrected: its
        // exported timestamp is the raw (skewed) begin minus the measured
        // 5ms offset.
        let want_us = (raw_push_begin as i64 - 5_000_000) as f64 / 1e3;
        let corrected = events.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("B")
                && e.get("pid").and_then(|p| p.as_f64()) == Some(node_pid)
                && e.get("args").and_then(|a| a.get("id")).and_then(|i| i.as_f64())
                    == Some(push_id as f64)
                && (e.get("ts").and_then(|t| t.as_f64()).unwrap_or(f64::MIN) - want_us).abs()
                    < 1.0
        });
        assert!(corrected, "node-lane timestamps must subtract the measured offset");
    }
}
