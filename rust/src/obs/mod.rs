//! Unified observability plane: a process-global, dependency-free metrics
//! registry (counters / gauges / log2-bucket histograms), per-thread span
//! tracing with Chrome trace-event export ([`trace`]), and a hand-rolled
//! Prometheus-text scrape endpoint ([`expo`]). See docs/OBSERVABILITY.md.
//!
//! Design constraints (docs/OBSERVABILITY.md has the full rationale):
//!
//! * **Hot-path cost is one relaxed atomic op.** Handles ([`Counter`],
//!   [`Gauge`], [`Histogram`]) are `Arc`-backed atomics created once at
//!   construction time; `inc`/`add`/`set` touch no locks and allocate
//!   nothing, so `dynalint`'s hot-path allocation check stays clean.
//! * **Registration is cold and named.** Every series registers through the
//!   [`obs_counter!`] / [`obs_gauge!`] / [`obs_histogram!`] macros with a
//!   `'static` string-literal name — the dynalint `metrics` check walks
//!   those call sites and holds each name to uniqueness, the `dynacomm_`
//!   prefix, and a docs/OBSERVABILITY.md catalog entry.
//! * **Instances, not globals.** Components that exist many times per
//!   process (slab pools, reply caches, codec tables) register one series
//!   per instance; the registry appends an `inst="N"` label so concurrent
//!   instances render as distinct Prometheus series, and weak registry
//!   entries are pruned once the owning instance drops. A constructor that
//!   registers several related series allocates **one** [`Inst`] via
//!   [`next_inst`] and passes it to each registration (the macros' third
//!   argument), so all of an instance's series share an `inst` value and
//!   can be joined on it.

pub mod clock;
pub mod critpath;
pub mod expo;
pub mod trace;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::util::sync::lock_or_die;

/// Number of histogram buckets: 31 finite log2 bounds plus `+Inf`.
pub const HIST_BUCKETS: usize = 32;

/// Upper bound of finite bucket `i`: `2^(i-6)`, i.e. 0.015625 … 2^24.
/// Values are unit-agnostic; ms-scale and byte-scale series both fit.
pub fn bucket_bound(i: usize) -> f64 {
    2.0f64.powi(i as i32 - 6)
}

fn bucket_index(v: f64) -> usize {
    let mut i = 0;
    while i < HIST_BUCKETS - 1 && v > bucket_bound(i) {
        i += 1;
    }
    i
}

/// Lock-free CAS-add of an f64 stored as bits in an `AtomicU64`.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// Lock-free CAS-max of an f64 stored as bits in an `AtomicU64`.
fn max_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) >= v {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Monotone counter. `inc` is a single relaxed `fetch_add`.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge holding an f64 (stored as bits). `set` is a single
/// relaxed store; `add`/`max` are short CAS loops for the rarer
/// increment/decrement and high-watermark shapes.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }
    pub fn add(&self, delta: f64) {
        add_f64(&self.0, delta);
    }
    pub fn max(&self, v: f64) {
        max_f64(&self.0, v);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Shared histogram state: log2 buckets + count + f64-bits sum.
#[derive(Debug)]
pub struct HistCore {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl HistCore {
    fn new() -> Self {
        HistCore {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
        }
    }
}

/// Log2-bucket histogram. `observe` is lock-free: one bucket `fetch_add`,
/// one count `fetch_add`, one CAS-add for the sum.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.0.sum_bits, v);
    }
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }
    /// Per-bucket (non-cumulative) counts, for tests and snapshots.
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.0.buckets[i].load(Ordering::Relaxed))
    }
    /// Estimated `q`-quantile (see [`quantile_from`]); `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        quantile_from(&self.bucket_counts(), q)
    }
}

/// Estimate the `q`-quantile (`0.0..=1.0`) of a log2-bucket distribution
/// by linear interpolation inside the covering bucket: the estimate is
/// exact at bucket boundaries and off by at most one bucket width within
/// one. Mass in the `+Inf` bucket clamps to the last finite bound — there
/// is nothing to interpolate toward. `None` when the histogram is empty.
pub fn quantile_from(buckets: &[u64; HIST_BUCKETS], q: f64) -> Option<f64> {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return None;
    }
    let rank = q.clamp(0.0, 1.0) * total as f64;
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let prev = cum as f64;
        cum += c;
        if cum as f64 >= rank {
            if i == HIST_BUCKETS - 1 {
                return Some(bucket_bound(HIST_BUCKETS - 2));
            }
            let lo = if i == 0 { 0.0 } else { bucket_bound(i - 1) };
            let hi = bucket_bound(i);
            let frac = ((rank - prev) / c as f64).clamp(0.0, 1.0);
            return Some(lo + (hi - lo) * frac);
        }
    }
    Some(bucket_bound(HIST_BUCKETS - 2))
}

enum Slot {
    Counter(Weak<AtomicU64>),
    Gauge(Weak<AtomicU64>),
    Histogram(Weak<HistCore>),
}

struct Entry {
    name: &'static str,
    labels: String,
    slot: Slot,
}

fn registry() -> &'static Mutex<Vec<Entry>> {
    static REGISTRY: OnceLock<Mutex<Vec<Entry>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Process-unique component-instance id, rendered as the `inst="N"`
/// label. Allocate **one per component instance** (in its constructor)
/// and pass it to every series that instance registers, so related series
/// — a pool's checkouts/recycled/allocations, a codec row's eight
/// counters — share an `inst` value and can be joined on it. Singleton
/// registrations may let the two-argument macro forms allocate a fresh id
/// implicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Inst(usize);

/// Allocate a fresh [`Inst`].
pub fn next_inst() -> Inst {
    static INSTANCES: AtomicUsize = AtomicUsize::new(0);
    Inst(INSTANCES.fetch_add(1, Ordering::Relaxed))
}

/// Concurrent instances of one component render as distinct Prometheus
/// series (rather than colliding on one name+labels) via the `inst` label.
fn full_labels(extra: &str, inst: Inst) -> String {
    if extra.is_empty() {
        format!("inst=\"{}\"", inst.0)
    } else {
        format!("{extra},inst=\"{}\"", inst.0)
    }
}

/// Register a counter series. Prefer the [`obs_counter!`] macro: the
/// dynalint `metrics` check audits macro call sites for name uniqueness
/// and docs/OBSERVABILITY.md coverage.
pub fn register_counter(name: &'static str, labels: &str, inst: Inst) -> Counter {
    let cell = Arc::new(AtomicU64::new(0));
    lock_or_die(registry(), "obs.registry").push(Entry {
        name,
        labels: full_labels(labels, inst),
        slot: Slot::Counter(Arc::downgrade(&cell)),
    });
    Counter(cell)
}

/// Register a gauge series (see [`register_counter`] for macro guidance).
pub fn register_gauge(name: &'static str, labels: &str, inst: Inst) -> Gauge {
    let cell = Arc::new(AtomicU64::new(0f64.to_bits()));
    lock_or_die(registry(), "obs.registry").push(Entry {
        name,
        labels: full_labels(labels, inst),
        slot: Slot::Gauge(Arc::downgrade(&cell)),
    });
    Gauge(cell)
}

/// Register a histogram series (see [`register_counter`] for macro guidance).
pub fn register_histogram(name: &'static str, labels: &str, inst: Inst) -> Histogram {
    let core = Arc::new(HistCore::new());
    lock_or_die(registry(), "obs.registry").push(Entry {
        name,
        labels: full_labels(labels, inst),
        slot: Slot::Histogram(Arc::downgrade(&core)),
    });
    Histogram(core)
}

/// Register a counter in the unified metrics registry.
///
/// `obs_counter!("dynacomm_x_total")` or
/// `obs_counter!("dynacomm_x_total", labels)` where `labels` is a
/// `key="value"` fragment (the registry appends `inst="N"` itself). A
/// constructor registering several related series passes one shared
/// [`Inst`](crate::obs::Inst) as a third argument —
/// `obs_counter!("dynacomm_x_total", labels, inst)` — so the instance's
/// series are joinable on their `inst` label.
#[macro_export]
macro_rules! obs_counter {
    ($name:literal) => {
        $crate::obs::register_counter($name, "", $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr) => {
        $crate::obs::register_counter($name, &$labels, $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr, $inst:expr) => {
        $crate::obs::register_counter($name, &$labels, $inst)
    };
}

/// Register a gauge in the unified metrics registry (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_gauge {
    ($name:literal) => {
        $crate::obs::register_gauge($name, "", $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr) => {
        $crate::obs::register_gauge($name, &$labels, $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr, $inst:expr) => {
        $crate::obs::register_gauge($name, &$labels, $inst)
    };
}

/// Register a histogram in the unified metrics registry (see
/// [`obs_counter!`]).
#[macro_export]
macro_rules! obs_histogram {
    ($name:literal) => {
        $crate::obs::register_histogram($name, "", $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr) => {
        $crate::obs::register_histogram($name, &$labels, $crate::obs::next_inst())
    };
    ($name:literal, $labels:expr, $inst:expr) => {
        $crate::obs::register_histogram($name, &$labels, $inst)
    };
}

enum Sample {
    Counter(u64),
    Gauge(f64),
    Histogram([u64; HIST_BUCKETS], u64, f64),
}

/// Snapshot the live registry, pruning entries whose owner has dropped.
fn collect() -> Vec<(&'static str, String, Sample)> {
    let mut reg = lock_or_die(registry(), "obs.registry");
    reg.retain(|e| match &e.slot {
        Slot::Counter(w) | Slot::Gauge(w) => w.strong_count() > 0,
        Slot::Histogram(w) => w.strong_count() > 0,
    });
    let mut out = Vec::with_capacity(reg.len());
    for e in reg.iter() {
        let sample = match &e.slot {
            Slot::Counter(w) => match w.upgrade() {
                Some(c) => Sample::Counter(c.load(Ordering::Relaxed)),
                None => continue,
            },
            Slot::Gauge(w) => match w.upgrade() {
                Some(g) => Sample::Gauge(f64::from_bits(g.load(Ordering::Relaxed))),
                None => continue,
            },
            Slot::Histogram(w) => match w.upgrade() {
                Some(h) => Sample::Histogram(
                    std::array::from_fn(|i| h.buckets[i].load(Ordering::Relaxed)),
                    h.count.load(Ordering::Relaxed),
                    f64::from_bits(h.sum_bits.load(Ordering::Relaxed)),
                ),
                None => continue,
            },
        };
        out.push((e.name, e.labels.clone(), sample));
    }
    out.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
    out
}

/// Render the whole registry in Prometheus text exposition format
/// (`# TYPE` comments plus `name{labels} value` lines; histograms expand
/// to cumulative `_bucket{le=...}` / `_sum` / `_count` series).
pub fn render_prometheus() -> String {
    let mut out = String::new();
    let mut last_type: Option<&'static str> = None;
    for (name, labels, sample) in collect() {
        if last_type != Some(name) {
            let kind = match sample {
                Sample::Counter(_) => "counter",
                Sample::Gauge(_) => "gauge",
                Sample::Histogram(..) => "histogram",
            };
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            last_type = Some(name);
        }
        match sample {
            Sample::Counter(v) => out.push_str(&format!("{name}{{{labels}}} {v}\n")),
            Sample::Gauge(v) => out.push_str(&format!("{name}{{{labels}}} {v}\n")),
            Sample::Histogram(buckets, count, sum) => {
                let mut cum = 0u64;
                for (i, b) in buckets.iter().enumerate() {
                    cum += b;
                    if i < HIST_BUCKETS - 1 {
                        let le = bucket_bound(i);
                        out.push_str(&format!("{name}_bucket{{{labels},le=\"{le}\"}} {cum}\n"));
                    } else {
                        out.push_str(&format!("{name}_bucket{{{labels},le=\"+Inf\"}} {cum}\n"));
                    }
                }
                out.push_str(&format!("{name}_sum{{{labels}}} {sum}\n"));
                out.push_str(&format!("{name}_count{{{labels}}} {count}\n"));
            }
        }
    }
    out
}

/// Flat `(series, value)` snapshot for embedding in `WorkerReport` and the
/// bench JSON: counters and gauges one entry each, histograms contribute
/// `_count`, `_sum`, and interpolated `_p50` / `_p99` quantile estimates.
/// Entries come back in deterministic rendered-name sort order (collect()
/// already orders by `(name, labels)`; the final sort also fixes the
/// relative order of one histogram's expanded suffixes) so scrapes and
/// `BENCH_wire.json` metric blocks diff cleanly across runs.
pub fn snapshot_pairs() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (name, labels, sample) in collect() {
        match sample {
            Sample::Counter(v) => out.push((format!("{name}{{{labels}}}"), v as f64)),
            Sample::Gauge(v) => out.push((format!("{name}{{{labels}}}"), v)),
            Sample::Histogram(buckets, count, sum) => {
                out.push((format!("{name}_count{{{labels}}}"), count as f64));
                out.push((format!("{name}_sum{{{labels}}}"), sum));
                if let (Some(p50), Some(p99)) =
                    (quantile_from(&buckets, 0.50), quantile_from(&buckets, 0.99))
                {
                    out.push((format!("{name}_p50{{{labels}}}"), p50));
                    out.push((format!("{name}_p99{{{labels}}}"), p99));
                }
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Sum a series' value across all live instances whose rendered name
/// matches `name` exactly (labels ignored). Histograms sum their counts.
pub fn series_total(name: &str) -> f64 {
    let mut total = 0.0;
    for (n, _, sample) in collect() {
        if n == name {
            total += match sample {
                Sample::Counter(v) => v as f64,
                Sample::Gauge(v) => v,
                Sample::Histogram(_, count, _) => count as f64,
            };
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_inc_add_get() {
        let c = register_counter("dynacomm_test_ctr", "", next_inst());
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_set_add_max() {
        let g = register_gauge("dynacomm_test_gauge", "", next_inst());
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        g.add(1.0);
        assert_eq!(g.get(), 3.5);
        g.add(-3.5);
        assert_eq!(g.get(), 0.0);
        g.max(7.0);
        g.max(1.0);
        assert_eq!(g.get(), 7.0);
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let h = register_histogram("dynacomm_test_hist", "", next_inst());
        // bound(6) = 1.0, so 0.5 lands at index 5, 1.0 at 6, 1.5 at 7.
        h.observe(0.5);
        h.observe(1.0);
        h.observe(1.5);
        h.observe(1e12); // beyond the last finite bound -> +Inf bucket
        assert_eq!(h.count(), 4);
        assert!((h.sum() - (3.0 + 1e12)).abs() < 1e-3);
        let b = h.bucket_counts();
        assert_eq!(b[5], 1);
        assert_eq!(b[6], 1);
        assert_eq!(b[7], 1);
        assert_eq!(b[HIST_BUCKETS - 1], 1);
    }

    #[test]
    fn bucket_index_is_monotone_and_clamped() {
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(0.0), 0);
        let mut prev = 0;
        let mut v = 0.001;
        while v < 1e9 {
            let i = bucket_index(v);
            assert!(i >= prev, "index regressed at {v}");
            assert!(i < HIST_BUCKETS);
            if i < HIST_BUCKETS - 1 {
                assert!(v <= bucket_bound(i));
            }
            if i > 0 {
                assert!(v > bucket_bound(i - 1));
            }
            prev = i;
            v *= 1.7;
        }
    }

    #[test]
    fn render_has_type_lines_and_distinct_instances() {
        let a = register_counter("dynacomm_test_render", "shard=\"0\"", next_inst());
        let b = register_counter("dynacomm_test_render", "shard=\"0\"", next_inst());
        a.inc();
        b.add(2);
        let text = render_prometheus();
        assert!(text.contains("# TYPE dynacomm_test_render counter"));
        // Same name+labels, two instances: both render thanks to inst="N".
        let rows: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("dynacomm_test_render{"))
            .collect();
        assert!(rows.len() >= 2, "expected two instance rows, got {rows:?}");
        assert!(rows.iter().all(|r| r.contains("shard=\"0\",inst=\"")));
    }

    #[test]
    fn dropped_instances_are_pruned() {
        let c = register_counter("dynacomm_test_pruned", "", next_inst());
        c.inc();
        assert!(render_prometheus().contains("dynacomm_test_pruned{"));
        drop(c);
        assert!(!render_prometheus().contains("dynacomm_test_pruned{"));
    }

    #[test]
    fn snapshot_pairs_expands_histograms() {
        let h = register_histogram("dynacomm_test_snap_hist", "", next_inst());
        h.observe(2.0);
        h.observe(4.0);
        let pairs = snapshot_pairs();
        let count = pairs
            .iter()
            .find(|(k, _)| k.starts_with("dynacomm_test_snap_hist_count{"))
            .expect("count entry");
        let sum = pairs
            .iter()
            .find(|(k, _)| k.starts_with("dynacomm_test_snap_hist_sum{"))
            .expect("sum entry");
        assert_eq!(count.1, 2.0);
        assert_eq!(sum.1, 6.0);
    }

    #[test]
    fn histogram_quantile_interpolates_within_a_bucket() {
        let h = register_histogram("dynacomm_test_quant", "", next_inst());
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantiles");
        for v in [0.5, 1.0, 2.0, 4.0] {
            h.observe(v);
        }
        // Ranks that land on bucket boundaries are exact (each observation
        // sits on its bucket's upper bound)...
        assert_eq!(h.quantile(0.25), Some(0.5));
        assert_eq!(h.quantile(1.0), Some(4.0));
        // ...and interior ranks stay within one log2 boundary of the
        // exact order statistic.
        let p50 = h.quantile(0.5).unwrap();
        assert!((0.5..=2.0).contains(&p50), "p50 within one bucket of exact: {p50}");
        // Mass inside one bucket interpolates linearly across it: 100
        // samples of 0.75 live in (0.5, 1.0], so every quantile estimate
        // is within that bucket — one boundary of the exact 0.75.
        let u = register_histogram("dynacomm_test_quant_uniform", "", next_inst());
        for _ in 0..100 {
            u.observe(0.75);
        }
        let p50 = u.quantile(0.5).unwrap();
        assert!((p50 - 0.75).abs() <= 0.25, "within one bucket boundary: {p50}");
        // +Inf-bucket mass clamps to the last finite bound.
        let inf = register_histogram("dynacomm_test_quant_inf", "", next_inst());
        inf.observe(1e12);
        assert_eq!(inf.quantile(0.99), Some(bucket_bound(HIST_BUCKETS - 2)));
    }

    #[test]
    fn snapshot_pairs_is_sorted_and_stable() {
        // Register deliberately out of order; snapshots come back in
        // rendered-name sort order, stable across calls. (Assertions on
        // specific series filter to this test's own prefix — the registry
        // is process-global and other tests mutate it concurrently.)
        let _b = register_counter("dynacomm_test_sortz", "", next_inst());
        let _a = register_counter("dynacomm_test_sorta", "", next_inst());
        let h = register_histogram("dynacomm_test_sorth", "", next_inst());
        h.observe(1.0);
        let keys = |pairs: &[(String, f64)]| -> Vec<String> {
            pairs
                .iter()
                .map(|(k, _)| k.clone())
                .filter(|k| k.starts_with("dynacomm_test_sort"))
                .collect()
        };
        let p1 = snapshot_pairs();
        let all: Vec<&String> = p1.iter().map(|(k, _)| k).collect();
        assert!(all.windows(2).all(|w| w[0] <= w[1]), "whole snapshot sorted");
        let k1 = keys(&p1);
        let k2 = keys(&snapshot_pairs());
        assert_eq!(k1, k2, "same registrations, same order");
        assert_eq!(k1.len(), 6, "2 counters + count/sum/p50/p99: {k1:?}");
        // Histogram expansion carries the interpolated quantiles.
        assert!(k1.iter().any(|k| k.starts_with("dynacomm_test_sorth_p50{")));
        assert!(k1.iter().any(|k| k.starts_with("dynacomm_test_sorth_p99{")));
        assert!(k1[0].starts_with("dynacomm_test_sorta{"), "sorta before sorth/sortz: {k1:?}");
        assert!(k1[5].starts_with("dynacomm_test_sortz{"), "sortz last: {k1:?}");
    }

    #[test]
    fn shared_inst_joins_related_series() {
        // One component instance registering several series hands the same
        // Inst to each, so they render with one joinable inst value.
        let inst = next_inst();
        let c = register_counter("dynacomm_test_inst_ctr", "", inst);
        let h = register_histogram("dynacomm_test_inst_hist", "", inst);
        c.inc();
        h.observe(1.0);
        let text = render_prometheus();
        let inst_of = |name: &str| -> String {
            let line = text
                .lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("no {name} row"));
            line[line.find("inst=").unwrap()..line.find('}').unwrap()].to_string()
        };
        assert_eq!(
            inst_of("dynacomm_test_inst_ctr{"),
            inst_of("dynacomm_test_inst_hist_count{"),
            "related series of one instance must share inst"
        );
    }

    #[test]
    fn series_total_sums_instances() {
        let a = register_counter("dynacomm_test_total", "", next_inst());
        let b = register_counter("dynacomm_test_total", "", next_inst());
        a.add(3);
        b.add(4);
        assert_eq!(series_total("dynacomm_test_total"), 7.0);
    }
}
