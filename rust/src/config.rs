//! Configuration system: every experiment (simulated or real) is described
//! by a [`SystemConfig`] — network condition, device speed, cluster shape,
//! model, batch size, and scheduling strategy. Configs load from JSON files
//! or CLI flags and default to the paper's testbed (Section V-A).

use crate::net::codec::CodecId;
use crate::ps::sync::SyncMode;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Network condition between the edge devices and the parameter servers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// Round-trip time edge<->cloud in milliseconds (paper: ~10 ms avg).
    pub rtt_ms: f64,
    /// Per-worker link bandwidth in Gbit/s (paper: up to 10 Gbps).
    pub bandwidth_gbps: f64,
    /// Per-mini-procedure setup overhead Δt in milliseconds. The paper
    /// measures Δt + first-layer costs around 14 ms with ~10 ms RTT
    /// (Table I); with one-way latency (5 ms) accounted separately, the
    /// setup/coordination component defaults to 9 ms.
    pub delta_t_ms: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig { rtt_ms: 10.0, bandwidth_gbps: 10.0, delta_t_ms: 9.0 }
    }
}

impl NetworkConfig {
    /// Time in ms to move `bytes` over this link once a transmission is in
    /// flight: latency (one-way) + serialization at the bottleneck rate.
    pub fn transfer_ms(&self, bytes: f64) -> f64 {
        self.rtt_ms / 2.0 + bytes * 8.0 / (self.bandwidth_gbps * 1e9) * 1e3
    }

    /// Full cost of one transmission mini-procedure carrying `bytes`:
    /// Δt (setup + coordination) plus flight time.
    pub fn mini_procedure_ms(&self, bytes: f64) -> f64 {
        self.delta_t_ms + self.transfer_ms(bytes)
    }
}

/// Edge-device compute capability.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceConfig {
    /// Sustained GFLOP/s of one edge device. Calibrated from the paper's
    /// own Table II: 4.46 VGG-19 samples/s per worker × ~59 GFLOP
    /// (fwd+bwd) per sample ≈ 275 GFLOP/s sustained with MKL-DNN on the
    /// 4-core Xeon E3 testbed.
    pub gflops: f64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig { gflops: 275.0 }
    }
}

impl DeviceConfig {
    /// Milliseconds to execute `flops` floating-point operations.
    pub fn compute_ms(&self, flops: f64) -> f64 {
        flops / (self.gflops * 1e9) * 1e3
    }
}

/// Scheduling strategy selector (Section V-A3 competitors).
///
/// This enum is a thin **parse/name shim** for configs and CLI flags: the
/// actual strategies live behind the `sched::Scheduler` trait and are
/// instantiated through `sched::registry` (which also hosts entries this
/// enum never had, e.g. `slicing`). Keep it in sync with
/// `sched::registry::NAMES`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Default PS: one transmission per procedure, strictly sequential.
    Sequential,
    /// Poseidon-style layer-by-layer transmission (LBL).
    LayerByLayer,
    /// iBatch/iPart greedy batching (Wang et al.).
    IBatch,
    /// This paper: DP-optimal decomposition.
    DynaComm,
}

impl Strategy {
    pub const ALL: [Strategy; 4] = [
        Strategy::Sequential,
        Strategy::LayerByLayer,
        Strategy::IBatch,
        Strategy::DynaComm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Sequential => "sequential",
            Strategy::LayerByLayer => "lbl",
            Strategy::IBatch => "ibatch",
            Strategy::DynaComm => "dynacomm",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Some(Strategy::Sequential),
            "lbl" | "layer-by-layer" | "layerbylayer" => Some(Strategy::LayerByLayer),
            "ibatch" | "ipart" => Some(Strategy::IBatch),
            "dynacomm" | "dp" => Some(Strategy::DynaComm),
            _ => None,
        }
    }
}

/// Fleet topology between the edge workers and the cloud shards
/// (`ps::agg`, docs/TOPOLOGY.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Every worker speaks directly to the cloud shards.
    Flat,
    /// Workers are grouped behind regional aggregators that combine
    /// pushes and share pulls, with an independently configured
    /// regional→cloud hop.
    Regional,
}

impl Tier {
    pub const ALL: [Tier; 2] = [Tier::Flat, Tier::Regional];

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Flat => "flat",
            Tier::Regional => "regional",
        }
    }

    pub fn parse(s: &str) -> Option<Tier> {
        match s.to_ascii_lowercase().as_str() {
            "flat" | "direct" => Some(Tier::Flat),
            "regional" | "tiered" => Some(Tier::Regional),
            _ => None,
        }
    }
}

/// Complete description of one experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub net: NetworkConfig,
    pub device: DeviceConfig,
    /// Number of edge devices (paper testbed: 8).
    pub workers: usize,
    /// Number of parameter-server shards (paper testbed: 4).
    pub servers: usize,
    /// Aggregate server-side ingress/egress bandwidth in Gbit/s; worker
    /// links contend for it in the scalability model (Fig. 11).
    pub server_bandwidth_gbps: f64,
    pub model: String,
    pub batch: usize,
    pub strategy: Strategy,
    /// DynaComm re-plan gain threshold, ms (see
    /// `sched::dynacomm::DynaCommScheduler`): 0 re-plans on every
    /// scheduler call; negative (the default,
    /// `sched::dynacomm::GAIN_THRESHOLD_AUTO`, spelled `auto` in configs
    /// and flags) derives the threshold at run time from the measured DP
    /// wall-clock vs the comm idle window. An explicit value overrides
    /// AUTO.
    pub gain_threshold_ms: f64,
    /// Wire codec for parameter/gradient transfers (`net::codec`,
    /// `--codec {fp32,fp16,int8}`): shrinks bytes-on-wire, which both the
    /// real wire path and the scheduler's transmission-cost model consume
    /// (compressed transfers widen the overlap window, so the DP
    /// re-segments).
    pub codec: CodecId,
    /// Parameter-server synchronization mode (`ps::sync`,
    /// `--sync {bsp,ssp,asp}`): BSP is the paper's barrier; SSP/ASP relax
    /// it for heterogeneous fleets (the straggler model in
    /// `sim::straggler` scores the trade).
    pub sync: SyncMode,
    /// SSP staleness bound (`--staleness-bound`): iterations a worker may
    /// run ahead of the slowest. Must be 0 outside SSP.
    pub staleness_bound: u32,
    /// Fleet topology (`--tier {flat,regional}`, docs/TOPOLOGY.md):
    /// `regional` inserts `⌈workers / group_size⌉` aggregators between
    /// the edge fleet and the cloud shards.
    pub tier: Tier,
    /// Edge workers per regional aggregator (`--group-size`; ignored
    /// under the flat tier). Must be ≥ 1.
    pub group_size: usize,
    /// Regional→cloud hop sync mode (`--agg-sync`); the edge→regional
    /// hop keeps using `sync`. Under SSP the hop shares
    /// `staleness_bound`.
    pub agg_sync: SyncMode,
    /// Regional→cloud hop wire codec (`--agg-codec`); the edge→regional
    /// hop keeps using `codec`.
    pub agg_codec: CodecId,
    /// Pull/push I/O deadline in ms (`--io-timeout-ms`, `docs/FAULTS.md`):
    /// armed on every worker→shard and aggregator→cloud socket so a dead
    /// peer fails the blocked read within the window instead of hanging
    /// the fleet. 0 (the default) disables. Under BSP the deadline must
    /// comfortably exceed the slowest straggler's barrier wait, which
    /// travels over the same sockets.
    pub io_timeout_ms: u64,
    /// Prometheus scrape listener (`--metrics-addr`,
    /// docs/OBSERVABILITY.md): when set, the trainer serves text-format
    /// snapshots of the obs registry at this address. `host:port` — the
    /// host may be an IP or a resolvable name (`localhost:9461`), and
    /// port 0 picks an ephemeral one. `None` disables the listener.
    pub metrics_addr: Option<String>,
    /// Chrome trace-event JSON output path (`--trace-out`): when set,
    /// span tracing is armed for the run and the per-thread span rings
    /// are exported here on shutdown. `None` leaves tracing disarmed.
    pub trace_out: Option<String>,
    /// Clock-probe cadence in iterations (`--clock-probe-every`,
    /// docs/OBSERVABILITY.md): every worker re-measures its per-shard
    /// clock offset this often, on top of the burst every session runs at
    /// establish. 0 disables the periodic re-probes (the establish burst
    /// still runs — the merged trace always has an offset per lane).
    pub clock_probe_every: usize,
}

/// Check a `--metrics-addr` spelling is a plausible `host:port`: non-empty
/// host, valid port. Hostnames (`localhost:9461`) pass — resolution is the
/// listener's job at bind time, exactly like `TcpListener::bind` — so the
/// check stays purely syntactic and never touches the resolver.
pub fn validate_metrics_addr(addr: &str) -> anyhow::Result<()> {
    let ok = addr
        .rsplit_once(':')
        .is_some_and(|(host, port)| !host.is_empty() && port.parse::<u16>().is_ok());
    anyhow::ensure!(
        ok,
        "bad metrics addr '{addr}' (want host:port, e.g. 127.0.0.1:9461 or localhost:9461)"
    );
    Ok(())
}

/// Parse a `gain-threshold-ms` spelling: `auto` (case-insensitive) or a
/// millisecond count.
pub fn parse_gain_threshold(s: &str) -> Option<f64> {
    if s.eq_ignore_ascii_case("auto") {
        return Some(crate::sched::dynacomm::GAIN_THRESHOLD_AUTO);
    }
    s.parse::<f64>().ok()
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            net: NetworkConfig::default(),
            device: DeviceConfig::default(),
            workers: 8,
            servers: 4,
            server_bandwidth_gbps: 40.0,
            model: "resnet152".to_string(),
            batch: 32,
            strategy: Strategy::DynaComm,
            gain_threshold_ms: crate::sched::dynacomm::GAIN_THRESHOLD_AUTO,
            codec: CodecId::Fp32,
            sync: SyncMode::Bsp,
            staleness_bound: 0,
            tier: Tier::Flat,
            group_size: 4,
            agg_sync: SyncMode::Bsp,
            agg_codec: CodecId::Fp32,
            io_timeout_ms: 0,
            metrics_addr: None,
            trace_out: None,
            clock_probe_every: 64,
        }
    }
}

impl SystemConfig {
    /// Scheduler tuning knobs carried by this config, in the form
    /// `sched::registry::create_for_with` consumes.
    pub fn scheduler_params(&self) -> crate::sched::registry::SchedulerParams {
        crate::sched::registry::SchedulerParams {
            gain_threshold_ms: self.gain_threshold_ms,
            ..Default::default()
        }
    }

    /// Overlay CLI flags onto the defaults (or a loaded config).
    pub fn apply_args(mut self, args: &Args) -> SystemConfig {
        self.net.rtt_ms = args.f64("rtt-ms", self.net.rtt_ms);
        self.net.bandwidth_gbps = args.f64("bandwidth-gbps", self.net.bandwidth_gbps);
        self.net.delta_t_ms = args.f64("delta-t-ms", self.net.delta_t_ms);
        self.device.gflops = args.f64("gflops", self.device.gflops);
        self.workers = args.usize("workers", self.workers);
        self.servers = args.usize("servers", self.servers);
        self.server_bandwidth_gbps =
            args.f64("server-bandwidth-gbps", self.server_bandwidth_gbps);
        self.model = args.get_or("model", &self.model);
        self.batch = args.usize("batch", self.batch);
        if let Some(s) = args.get("gain-threshold-ms") {
            self.gain_threshold_ms = parse_gain_threshold(s)
                .unwrap_or_else(|| panic!("bad --gain-threshold-ms '{s}'"));
        }
        if let Some(s) = args.get("strategy") {
            self.strategy = Strategy::parse(s)
                .unwrap_or_else(|| panic!("unknown strategy '{s}'"));
        }
        if let Some(s) = args.get("codec") {
            self.codec = CodecId::parse(s)
                .unwrap_or_else(|| panic!("unknown codec '{s}' (fp32|fp16|int8)"));
        }
        if let Some(s) = args.get("sync") {
            self.sync = SyncMode::parse(s)
                .unwrap_or_else(|| panic!("unknown sync mode '{s}' (bsp|ssp|asp)"));
        }
        self.staleness_bound =
            args.usize("staleness-bound", self.staleness_bound as usize) as u32;
        crate::ps::sync::SyncConfig::new(self.sync, self.staleness_bound)
            .unwrap_or_else(|e| panic!("{e}"));
        if let Some(s) = args.get("tier") {
            self.tier = Tier::parse(s)
                .unwrap_or_else(|| panic!("unknown tier '{s}' (flat|regional)"));
        }
        self.group_size = args.usize("group-size", self.group_size);
        if let Some(s) = args.get("agg-sync") {
            self.agg_sync = SyncMode::parse(s)
                .unwrap_or_else(|| panic!("unknown sync mode '{s}' (bsp|ssp|asp)"));
        }
        if let Some(s) = args.get("agg-codec") {
            self.agg_codec = CodecId::parse(s)
                .unwrap_or_else(|| panic!("unknown codec '{s}' (fp32|fp16|int8)"));
        }
        self.io_timeout_ms = args.usize("io-timeout-ms", self.io_timeout_ms as usize) as u64;
        if let Some(a) = args.get("metrics-addr") {
            validate_metrics_addr(a).unwrap_or_else(|e| panic!("{e}"));
            self.metrics_addr = Some(a.to_string());
        }
        if let Some(p) = args.get("trace-out") {
            self.trace_out = Some(p.to_string());
        }
        self.clock_probe_every = args.usize("clock-probe-every", self.clock_probe_every);
        assert!(self.group_size >= 1, "--group-size must be >= 1");
        self.agg_sync_config().unwrap_or_else(|e| panic!("{e}"));
        self
    }

    /// The regional→cloud hop's sync configuration: `agg_sync`, sharing
    /// `staleness_bound` when that hop runs SSP.
    pub fn agg_sync_config(&self) -> anyhow::Result<crate::ps::sync::SyncConfig> {
        let bound = if self.agg_sync == SyncMode::Ssp { self.staleness_bound } else { 0 };
        crate::ps::sync::SyncConfig::new(self.agg_sync, bound)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SystemConfig> {
        let mut c = SystemConfig::default();
        let num = |key: &str, dflt: f64| -> f64 {
            j.get(key).and_then(Json::as_f64).unwrap_or(dflt)
        };
        c.net.rtt_ms = num("rtt_ms", c.net.rtt_ms);
        c.net.bandwidth_gbps = num("bandwidth_gbps", c.net.bandwidth_gbps);
        c.net.delta_t_ms = num("delta_t_ms", c.net.delta_t_ms);
        c.device.gflops = num("gflops", c.device.gflops);
        c.workers = num("workers", c.workers as f64) as usize;
        c.servers = num("servers", c.servers as f64) as usize;
        c.server_bandwidth_gbps = num("server_bandwidth_gbps", c.server_bandwidth_gbps);
        c.batch = num("batch", c.batch as f64) as usize;
        // Accepts a number or the string "auto".
        if let Some(g) = j.get("gain_threshold_ms") {
            if let Some(v) = g.as_f64() {
                c.gain_threshold_ms = v;
            } else if let Some(s) = g.as_str() {
                c.gain_threshold_ms = parse_gain_threshold(s)
                    .ok_or_else(|| anyhow::anyhow!("bad gain_threshold_ms '{s}'"))?;
            }
        }
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            c.model = m.to_string();
        }
        if let Some(s) = j.get("strategy").and_then(Json::as_str) {
            c.strategy = Strategy::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown strategy '{s}'"))?;
        }
        if let Some(s) = j.get("codec").and_then(Json::as_str) {
            c.codec = CodecId::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown codec '{s}'"))?;
        }
        if let Some(s) = j.get("sync").and_then(Json::as_str) {
            c.sync = SyncMode::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown sync mode '{s}'"))?;
        }
        c.staleness_bound = num("staleness_bound", c.staleness_bound as f64) as u32;
        crate::ps::sync::SyncConfig::new(c.sync, c.staleness_bound)?;
        if let Some(s) = j.get("tier").and_then(Json::as_str) {
            c.tier = Tier::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown tier '{s}'"))?;
        }
        c.group_size = num("group_size", c.group_size as f64) as usize;
        if let Some(s) = j.get("agg_sync").and_then(Json::as_str) {
            c.agg_sync = SyncMode::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown sync mode '{s}'"))?;
        }
        if let Some(s) = j.get("agg_codec").and_then(Json::as_str) {
            c.agg_codec = CodecId::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown codec '{s}'"))?;
        }
        c.io_timeout_ms = num("io_timeout_ms", c.io_timeout_ms as f64) as u64;
        if let Some(a) = j.get("metrics_addr").and_then(Json::as_str) {
            validate_metrics_addr(a)?;
            c.metrics_addr = Some(a.to_string());
        }
        if let Some(p) = j.get("trace_out").and_then(Json::as_str) {
            c.trace_out = Some(p.to_string());
        }
        c.clock_probe_every = num("clock_probe_every", c.clock_probe_every as f64) as usize;
        anyhow::ensure!(c.group_size >= 1, "group_size must be >= 1");
        c.agg_sync_config()?;
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rtt_ms", Json::Num(self.net.rtt_ms)),
            ("bandwidth_gbps", Json::Num(self.net.bandwidth_gbps)),
            ("delta_t_ms", Json::Num(self.net.delta_t_ms)),
            ("gflops", Json::Num(self.device.gflops)),
            ("workers", Json::Num(self.workers as f64)),
            ("servers", Json::Num(self.servers as f64)),
            ("server_bandwidth_gbps", Json::Num(self.server_bandwidth_gbps)),
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("strategy", Json::Str(self.strategy.name().to_string())),
            ("codec", Json::Str(self.codec.name().to_string())),
            ("sync", Json::Str(self.sync.name().to_string())),
            ("staleness_bound", Json::Num(self.staleness_bound as f64)),
            ("tier", Json::Str(self.tier.name().to_string())),
            ("group_size", Json::Num(self.group_size as f64)),
            ("agg_sync", Json::Str(self.agg_sync.name().to_string())),
            ("agg_codec", Json::Str(self.agg_codec.name().to_string())),
            ("io_timeout_ms", Json::Num(self.io_timeout_ms as f64)),
            ("clock_probe_every", Json::Num(self.clock_probe_every as f64)),
            (
                "gain_threshold_ms",
                if self.gain_threshold_ms < 0.0 {
                    Json::Str("auto".to_string())
                } else {
                    Json::Num(self.gain_threshold_ms)
                },
            ),
        ];
        // The obs knobs are opt-in: unset knobs are omitted entirely so
        // configs written before they existed round-trip byte-stable.
        if let Some(a) = &self.metrics_addr {
            fields.push(("metrics_addr", Json::Str(a.clone())));
        }
        if let Some(p) = &self.trace_out {
            fields.push(("trace_out", Json::Str(p.clone())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_scales_with_size() {
        let net = NetworkConfig::default();
        let small = net.transfer_ms(1e3);
        let big = net.transfer_ms(1e9);
        assert!(big > small);
        // 1 GB over 10 Gbps ~ 800 ms + 5 ms latency.
        assert!((big - 805.0).abs() < 1.0, "{big}");
    }

    #[test]
    fn mini_procedure_includes_delta_t() {
        let net = NetworkConfig::default();
        assert!((net.mini_procedure_ms(0.0) - (9.0 + 5.0)).abs() < 1e-9);
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("nope"), None);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SystemConfig::default();
        c.batch = 16;
        c.model = "vgg19".into();
        c.strategy = Strategy::IBatch;
        c.gain_threshold_ms = 3.5;
        c.codec = CodecId::Int8;
        let j = c.to_json();
        let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn args_overlay() {
        let args = Args::parse(
            [
                "--batch=64",
                "--strategy",
                "lbl",
                "--rtt-ms",
                "5",
                "--gain-threshold-ms",
                "2.5",
                "--codec",
                "fp16",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let c = SystemConfig::default().apply_args(&args);
        assert_eq!(c.batch, 64);
        assert_eq!(c.strategy, Strategy::LayerByLayer);
        assert_eq!(c.net.rtt_ms, 5.0);
        assert_eq!(c.gain_threshold_ms, 2.5);
        assert_eq!(c.scheduler_params().gain_threshold_ms, 2.5);
        assert_eq!(c.codec, CodecId::Fp16);
        // Default stays the uncompressed wire format.
        assert_eq!(SystemConfig::default().codec, CodecId::Fp32);
    }

    #[test]
    fn sync_knobs_roundtrip_and_validate() {
        let mut c = SystemConfig::default();
        assert_eq!(c.sync, SyncMode::Bsp);
        assert_eq!(c.staleness_bound, 0);
        c.sync = SyncMode::Ssp;
        c.staleness_bound = 4;
        let j = c.to_json();
        let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        // Flags overlay.
        let args = Args::parse(
            ["--sync", "asp"].iter().map(|s| s.to_string()),
        );
        let c = SystemConfig::default().apply_args(&args);
        assert_eq!(c.sync, SyncMode::Asp);
        // A bound outside SSP is refused at config load, not at run time.
        let bad = r#"{"sync":"bsp","staleness_bound":3}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn tier_knobs_parse_roundtrip_and_validate() {
        for t in Tier::ALL {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("nope"), None);
        let mut c = SystemConfig::default();
        assert_eq!(c.tier, Tier::Flat);
        assert_eq!(c.group_size, 4);
        c.tier = Tier::Regional;
        c.group_size = 2;
        c.agg_sync = SyncMode::Asp;
        c.agg_codec = CodecId::Fp16;
        let j = c.to_json();
        let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        // Flags overlay.
        let args = Args::parse(
            [
                "--tier",
                "regional",
                "--group-size",
                "2",
                "--agg-sync",
                "asp",
                "--agg-codec",
                "int8",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let c = SystemConfig::default().apply_args(&args);
        assert_eq!(c.tier, Tier::Regional);
        assert_eq!(c.group_size, 2);
        assert_eq!(c.agg_sync, SyncMode::Asp);
        assert_eq!(c.agg_codec, CodecId::Int8);
        // The upstream hop shares the SSP bound only when it runs SSP.
        let c = SystemConfig {
            sync: SyncMode::Ssp,
            staleness_bound: 4,
            agg_sync: SyncMode::Ssp,
            ..SystemConfig::default()
        };
        assert_eq!(c.agg_sync_config().unwrap().staleness_bound, 4);
        let c = SystemConfig { agg_sync: SyncMode::Bsp, ..c };
        assert_eq!(c.agg_sync_config().unwrap().staleness_bound, 0);
        // A zero group size is refused at config load.
        let bad = r#"{"tier":"regional","group_size":0}"#;
        assert!(SystemConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn io_timeout_roundtrips_flags_and_json() {
        // Default: no deadline.
        assert_eq!(SystemConfig::default().io_timeout_ms, 0);
        // JSON round-trip.
        let c = SystemConfig { io_timeout_ms: 2_500, ..SystemConfig::default() };
        let j = c.to_json();
        let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.io_timeout_ms, 2_500);
        // Flags overlay.
        let args = Args::parse(
            ["--io-timeout-ms", "750"].iter().map(|s| s.to_string()),
        );
        assert_eq!(SystemConfig::default().apply_args(&args).io_timeout_ms, 750);
    }

    #[test]
    fn obs_knobs_roundtrip_flags_and_json() {
        // Defaults: no listener, no trace, and the knobs stay out of JSON.
        let d = SystemConfig::default();
        assert_eq!(d.metrics_addr, None);
        assert_eq!(d.trace_out, None);
        assert_eq!(d.clock_probe_every, 64);
        assert!(!d.to_json().to_string().contains("metrics_addr"));
        // JSON round-trip.
        let c = SystemConfig {
            metrics_addr: Some("127.0.0.1:9461".to_string()),
            trace_out: Some("trace.json".to_string()),
            clock_probe_every: 7,
            ..SystemConfig::default()
        };
        let back =
            SystemConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        // Flags overlay.
        let args = Args::parse(
            ["--metrics-addr", "0.0.0.0:0", "--trace-out", "t.json", "--clock-probe-every", "5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = SystemConfig::default().apply_args(&args);
        assert_eq!(c.metrics_addr.as_deref(), Some("0.0.0.0:0"));
        assert_eq!(c.trace_out.as_deref(), Some("t.json"));
        assert_eq!(c.clock_probe_every, 5);
        // A malformed address is rejected at JSON load, not at bind time.
        let bad = Json::obj(vec![("metrics_addr", Json::Str("not-an-addr".to_string()))]);
        assert!(SystemConfig::from_json(&bad).is_err());
        // Hostnames are as valid as IPs (resolution happens at bind);
        // missing hosts and non-numeric ports are not.
        assert!(validate_metrics_addr("localhost:9461").is_ok());
        assert!(validate_metrics_addr("[::1]:9461").is_ok());
        assert!(validate_metrics_addr(":9461").is_err());
        assert!(validate_metrics_addr("localhost:http").is_err());
    }

    #[test]
    fn gain_threshold_auto_spelling() {
        use crate::sched::dynacomm::GAIN_THRESHOLD_AUTO;
        // AUTO is the default; "auto" is accepted from flags and JSON; an
        // explicit number overrides it everywhere.
        assert_eq!(SystemConfig::default().gain_threshold_ms, GAIN_THRESHOLD_AUTO);
        assert_eq!(parse_gain_threshold("auto"), Some(GAIN_THRESHOLD_AUTO));
        assert_eq!(parse_gain_threshold("AUTO"), Some(GAIN_THRESHOLD_AUTO));
        assert_eq!(parse_gain_threshold("7.25"), Some(7.25));
        assert_eq!(parse_gain_threshold("nope"), None);
        let args = Args::parse(
            ["--gain-threshold-ms", "auto"].iter().map(|s| s.to_string()),
        );
        let c = SystemConfig { gain_threshold_ms: 9.0, ..SystemConfig::default() }
            .apply_args(&args);
        assert_eq!(c.gain_threshold_ms, GAIN_THRESHOLD_AUTO);
        // JSON round-trips AUTO as the string "auto".
        let j = c.to_json();
        let back = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.gain_threshold_ms, GAIN_THRESHOLD_AUTO);
    }
}
