//! PJRT runtime — loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python is never on the request path: after `make artifacts`, the
//! coordinator is self-contained.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::RuntimeClient;
pub use manifest::{ArtifactManifest, LayerArtifact};
pub use tensor::Tensor;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True if an artifact manifest exists at `dir` (used by integration tests
/// and examples to degrade gracefully before `make artifacts`).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
