//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One exported layer: shapes, artifact files, FLOP accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerArtifact {
    pub name: String,
    pub kind: String,
    pub w_shape: Vec<usize>,
    pub b_shape: Vec<usize>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub fwd_file: String,
    pub bwd_file: String,
    pub w_init: String,
    pub b_init: String,
    pub param_count: usize,
    pub fwd_flops: f64,
    pub bwd_flops: f64,
}

impl LayerArtifact {
    pub fn param_bytes(&self) -> usize {
        4 * self.param_count
    }

    pub fn w_count(&self) -> usize {
        self.w_shape.iter().product()
    }

    pub fn b_count(&self) -> usize {
        self.b_shape.iter().product()
    }
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub model: String,
    pub batch: usize,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub layers: Vec<LayerArtifact>,
    pub loss_file: String,
    pub full_fwd_file: String,
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: PathBuf, j: &Json) -> Result<ArtifactManifest> {
        let str_field = |o: &Json, k: &str| -> Result<String> {
            Ok(o.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing string field '{k}'"))?
                .to_string())
        };
        let usize_field = |o: &Json, k: &str| -> Result<usize> {
            o.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing numeric field '{k}'"))
        };
        let vec_field = |o: &Json, k: &str| -> Result<Vec<usize>> {
            o.get(k)
                .and_then(Json::as_usize_vec)
                .with_context(|| format!("manifest missing array field '{k}'"))
        };

        let mut layers = Vec::new();
        for l in j
            .get("layers")
            .and_then(Json::as_arr)
            .context("manifest missing 'layers'")?
        {
            layers.push(LayerArtifact {
                name: str_field(l, "name")?,
                kind: str_field(l, "kind")?,
                w_shape: vec_field(l, "w_shape")?,
                b_shape: vec_field(l, "b_shape")?,
                in_shape: vec_field(l, "in_shape")?,
                out_shape: vec_field(l, "out_shape")?,
                fwd_file: str_field(l, "fwd")?,
                bwd_file: str_field(l, "bwd")?,
                w_init: str_field(l, "w_init")?,
                b_init: str_field(l, "b_init")?,
                param_count: usize_field(l, "param_count")?,
                fwd_flops: l.get("fwd_flops").and_then(Json::as_f64).unwrap_or(0.0),
                bwd_flops: l.get("bwd_flops").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        anyhow::ensure!(!layers.is_empty(), "manifest has no layers");
        // The wire pipeline sizes per-layer slabs from `param_count`
        // (`param_bytes`) while tensor splitting sizes from the shapes;
        // reject a manifest where the two disagree here, instead of deep
        // in the pull path as a byte-count mismatch.
        for a in &layers {
            anyhow::ensure!(
                a.param_count == a.w_count() + a.b_count(),
                "layer {}: param_count {} != w+b element count {}",
                a.name,
                a.param_count,
                a.w_count() + a.b_count()
            );
        }

        Ok(ArtifactManifest {
            dir,
            model: str_field(j, "model")?,
            batch: usize_field(j, "batch")?,
            num_classes: usize_field(j, "num_classes")?,
            input_shape: vec_field(j, "input_shape")?,
            layers,
            loss_file: str_field(j, "loss")?,
            full_fwd_file: str_field(j, "full_fwd")?,
        })
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count across layers.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.param_count).sum()
    }

    /// Path of a manifest-relative file.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "edgecnn", "batch": 2, "seed": 0, "num_classes": 10,
        "input_shape": [32, 32, 3],
        "loss": "loss.hlo.txt", "full_fwd": "full_fwd.hlo.txt",
        "layers": [
            {"name": "conv1", "kind": "conv",
             "w_shape": [3,3,3,16], "b_shape": [16],
             "in_shape": [32,32,3], "out_shape": [32,32,16],
             "pool": false, "relu": true,
             "fwd": "conv1_fwd.hlo.txt", "bwd": "conv1_bwd.hlo.txt",
             "w_init": "init/conv1_w.bin", "b_init": "init/conv1_b.bin",
             "param_count": 448, "param_bytes": 1792,
             "fwd_flops": 1769472, "bwd_flops": 3538944}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = ArtifactManifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        assert_eq!(m.batch, 2);
        assert_eq!(m.depth(), 1);
        let l = &m.layers[0];
        assert_eq!(l.w_shape, vec![3, 3, 3, 16]);
        assert_eq!(l.param_count, 448);
        assert_eq!(l.param_bytes(), 1792);
        assert_eq!(l.w_count(), 432);
        assert_eq!(l.b_count(), 16);
        assert_eq!(m.path("loss.hlo.txt"), PathBuf::from("/tmp/x/loss.hlo.txt"));
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(ArtifactManifest::from_json(PathBuf::from("."), &j).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // Cross-check the Rust cost zoo against the Python export when the
        // artifacts have been built.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !crate::runtime::artifacts_available(dir) {
            return;
        }
        let m = ArtifactManifest::load(dir).unwrap();
        assert_eq!(m.model, "edgecnn");
        let zoo = crate::models::by_name("edgecnn").unwrap();
        assert_eq!(m.depth(), zoo.depth());
        for (a, z) in m.layers.iter().zip(&zoo.layers) {
            assert_eq!(a.param_count, z.params, "{}", a.name);
            assert_eq!(a.fwd_flops / m.batch as f64, z.fwd_flops, "{}", a.name);
        }
    }
}
