//! The PJRT client: compile HLO-text artifacts once, execute per layer.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos).

use anyhow::{Context, Result};

use super::manifest::ArtifactManifest;
use super::tensor::Tensor;

/// A loaded model: one compiled executable per layer direction + loss.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    fwd: Vec<xla::PjRtLoadedExecutable>,
    bwd: Vec<xla::PjRtLoadedExecutable>,
    loss: xla::PjRtLoadedExecutable,
    full_fwd: xla::PjRtLoadedExecutable,
}

impl RuntimeClient {
    /// Load and compile every artifact under `dir`.
    pub fn load(dir: &str) -> Result<RuntimeClient> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |rel: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.path(rel);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))
        };
        let mut fwd = Vec::with_capacity(manifest.depth());
        let mut bwd = Vec::with_capacity(manifest.depth());
        for layer in &manifest.layers {
            fwd.push(compile(&layer.fwd_file)?);
            bwd.push(compile(&layer.bwd_file)?);
        }
        let loss = compile(&manifest.loss_file)?;
        let full_fwd = compile(&manifest.full_fwd_file)?;
        Ok(RuntimeClient { client, manifest, fwd, bwd, loss, full_fwd })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Initial parameters from the exported `init/*.bin` files.
    pub fn initial_params(&self) -> Result<Vec<(Tensor, Tensor)>> {
        self.manifest
            .layers
            .iter()
            .map(|l| {
                let w = Tensor::from_bin_file(&self.manifest.path(&l.w_init), l.w_shape.clone())?;
                let b = Tensor::from_bin_file(&self.manifest.path(&l.b_init), l.b_shape.clone())?;
                Ok((w, b))
            })
            .collect()
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&Tensor],
    ) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(out.to_tuple()?)
    }

    /// Layer forward: `(w, b, x) -> y` with `y` of shape `[batch, out..]`.
    pub fn layer_fwd(&self, idx: usize, w: &Tensor, b: &Tensor, x: &Tensor) -> Result<Tensor> {
        let layer = &self.manifest.layers[idx];
        let outs = Self::run(&self.fwd[idx], &[w, b, x])?;
        anyhow::ensure!(outs.len() == 1, "layer fwd returned {} outputs", outs.len());
        let mut shape = vec![self.manifest.batch];
        shape.extend(&layer.out_shape);
        Tensor::from_literal(&outs[0], shape)
    }

    /// Layer backward: `(w, b, x, gy) -> (gw, gb, gx)`.
    pub fn layer_bwd(
        &self,
        idx: usize,
        w: &Tensor,
        b: &Tensor,
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let layer = &self.manifest.layers[idx];
        let outs = Self::run(&self.bwd[idx], &[w, b, x, gy])?;
        anyhow::ensure!(outs.len() == 3, "layer bwd returned {} outputs", outs.len());
        let gw = Tensor::from_literal(&outs[0], layer.w_shape.clone())?;
        let gb = Tensor::from_literal(&outs[1], layer.b_shape.clone())?;
        let mut xshape = vec![self.manifest.batch];
        xshape.extend(&layer.in_shape);
        let gx = Tensor::from_literal(&outs[2], xshape)?;
        Ok((gw, gb, gx))
    }

    /// Loss head: `(logits, onehot) -> (loss, glogits)`.
    pub fn loss(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor)> {
        let outs = Self::run(&self.loss, &[logits, onehot])?;
        anyhow::ensure!(outs.len() == 2, "loss returned {} outputs", outs.len());
        let loss = Tensor::from_literal(&outs[0], vec![])?;
        let glogits = Tensor::from_literal(
            &outs[1],
            vec![self.manifest.batch, self.manifest.num_classes],
        )?;
        Ok((loss.data[0], glogits))
    }

    /// Monolithic forward `(w1, b1, ..., wL, bL, x) -> logits` — used by
    /// integration tests to check layer-wise composition.
    pub fn full_fwd(&self, params: &[(Tensor, Tensor)], x: &Tensor) -> Result<Tensor> {
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(2 * params.len() + 1);
        for (w, b) in params {
            inputs.push(w);
            inputs.push(b);
        }
        inputs.push(x);
        let outs = Self::run(&self.full_fwd, &inputs)?;
        anyhow::ensure!(outs.len() == 1, "full fwd returned {} outputs", outs.len());
        Tensor::from_literal(
            &outs[0],
            vec![self.manifest.batch, self.manifest.num_classes],
        )
    }
}
