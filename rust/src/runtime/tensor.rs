//! Host-side f32 tensors and conversions to/from XLA literals and the wire
//! format's little-endian byte slabs.

use anyhow::Result;

use crate::net::slab;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn scalar(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "scalar() on non-scalar tensor");
        self.data[0]
    }

    /// Decode a tensor from a little-endian f32 byte slab (the wire and
    /// `init/*.bin` representation). The slab length must match the shape.
    pub fn from_le_bytes(shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        anyhow::ensure!(
            bytes.len() % slab::ELEM == 0,
            "slab of {} bytes is not f32-aligned",
            bytes.len()
        );
        anyhow::ensure!(
            bytes.len() / slab::ELEM == shape.iter().product::<usize>(),
            "slab has {} f32s, shape {:?} wants {}",
            bytes.len() / slab::ELEM,
            shape,
            shape.iter().product::<usize>()
        );
        Ok(Tensor { data: slab::to_f32s(bytes), shape })
    }

    /// Append this tensor's data to a byte slab, little-endian.
    pub fn extend_le_bytes(&self, dst: &mut Vec<u8>) {
        slab::extend_f32s(dst, &self.data);
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        anyhow::ensure!(
            data.len() == shape.iter().product::<usize>(),
            "literal has {} elements, shape {:?} wants {}",
            data.len(),
            shape,
            shape.iter().product::<usize>()
        );
        Ok(Tensor { shape, data })
    }

    /// Flat little-endian f32 file (the `init/*.bin` format aot.py writes).
    pub fn from_bin_file(path: &std::path::Path, shape: Vec<usize>) -> Result<Tensor> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "truncated f32 file {path:?}");
        Tensor::from_le_bytes(shape, &bytes)
            .map_err(|e| e.context(format!("reading {path:?}")))
    }

    /// In-place SGD step: `self -= lr * grad`.
    pub fn sgd_step(&mut self, grad: &Tensor, lr: f32) {
        assert_eq!(self.shape, grad.shape);
        for (w, g) in self.data.iter_mut().zip(&grad.data) {
            *w -= lr * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn sgd_step_moves_against_gradient() {
        let mut w = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let g = Tensor::new(vec![3], vec![1.0, -1.0, 0.0]);
        w.sgd_step(&g, 0.5);
        assert_eq!(w.data, vec![0.5, 2.5, 3.0]);
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -0.5, 2.5, 1e-8]);
        let mut slab = Vec::new();
        t.extend_le_bytes(&mut slab);
        let back = Tensor::from_le_bytes(vec![2, 2], &slab).unwrap();
        assert_eq!(back, t);
        assert!(Tensor::from_le_bytes(vec![5], &slab).is_err());
        assert!(Tensor::from_le_bytes(vec![4], &slab[..15]).is_err());
    }

    #[test]
    fn bin_file_roundtrip() {
        let dir = std::env::temp_dir().join("dynacomm_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals = [1.5f32, -2.25, 3.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::from_bin_file(&path, vec![3]).unwrap();
        assert_eq!(t.data, vals);
        assert!(Tensor::from_bin_file(&path, vec![4]).is_err());
    }
}
