//! Cross-module integration: the cost-model zoo feeding the schedulers,
//! with timelines validated against the paper's partial-order constraints
//! and against the exhaustive optimum where tractable.

use dynacomm::config::{Strategy, SystemConfig};
use dynacomm::models;
use dynacomm::sched::{self, bruteforce, registry, Decomposition, Scheduler};
use dynacomm::sim::{self, timeline};
use dynacomm::util::rng::Rng;

/// Every registry scheduler on every paper model yields a
/// constraint-satisfying mini-procedure timeline.
#[test]
fn all_scheduler_timelines_satisfy_constraints_on_paper_models() {
    let mut cfg = SystemConfig::default();
    for batch in [16, 32] {
        cfg.batch = batch;
        for model in models::paper_models() {
            let cv = model.cost_vectors(&cfg);
            for name in registry::NAMES {
                // The exhaustive oracle is only tractable at small depth;
                // its DP fallback is exercised by resnet152 (L=152 > cap).
                if name == "bruteforce" && bruteforce::intractable_in_tests(cv.depth()) {
                    continue;
                }
                let sp = registry::create(name).unwrap().plan(&cv);
                let f = timeline::forward_timeline(&cv, &sp.plan.fwd);
                timeline::check_forward_constraints(&f, cv.depth()).unwrap_or_else(
                    |e| panic!("{} {name} fwd: {e}", model.name),
                );
                let b = timeline::backward_timeline(&cv, &sp.plan.bwd);
                timeline::check_backward_constraints(&b, cv.depth()).unwrap_or_else(
                    |e| panic!("{} {name} bwd: {e}", model.name),
                );
            }
        }
    }
}

/// EdgeCNN is shallow enough (L=6) to brute-force: DynaComm must be exactly
/// optimal on the real workload's cost profile, across many conditions.
#[test]
fn dynacomm_exactly_optimal_on_edgecnn_profiles() {
    let model = models::by_name("edgecnn").unwrap();
    let mut cfg = SystemConfig::default();
    for batch in [4, 16, 64] {
        for bw in [0.5, 2.0, 10.0] {
            for dt in [0.5, 5.0, 20.0] {
                cfg.batch = batch;
                cfg.net.bandwidth_gbps = bw;
                cfg.net.delta_t_ms = dt;
                let cv = model.cost_vectors(&cfg);
                let sp = registry::create_for(Strategy::DynaComm).plan(&cv);
                let (_, best_f) = bruteforce::forward(&cv);
                let got_f = sched::eval_forward(&cv, &sp.plan.fwd).total;
                assert!(
                    (got_f - best_f).abs() < 1e-7,
                    "bs={batch} bw={bw} dt={dt}: {got_f} vs {best_f}"
                );
                // The scheduler's own prediction agrees with the oracle.
                assert!((sp.predicted_fwd_ms - best_f).abs() < 1e-7);
                let (_, best_b) = bruteforce::backward(&cv);
                let got_b = sched::eval_backward(&cv, &sp.plan.bwd).total;
                assert!((got_b - best_b).abs() < 1e-7);
            }
        }
    }
}

/// The Fig. 5/6 property at both batch sizes: DynaComm ≤ everything, and
/// Sequential is the normalization baseline.
#[test]
fn dynacomm_dominates_paper_grid() {
    let mut cfg = SystemConfig::default();
    for batch in [16, 32] {
        cfg.batch = batch;
        for model in models::paper_models() {
            let cv = model.cost_vectors(&cfg);
            let dyna = sim::simulate_cv(&cv, Strategy::DynaComm);
            for s in Strategy::ALL {
                let r = sim::simulate_cv(&cv, s);
                assert!(
                    dyna.breakdown.fwd.total <= r.breakdown.fwd.total + 1e-6,
                    "{} bs={batch} {} fwd",
                    model.name,
                    s.name()
                );
                assert!(
                    dyna.breakdown.bwd.total <= r.breakdown.bwd.total + 1e-6,
                    "{} bs={batch} {} bwd",
                    model.name,
                    s.name()
                );
            }
        }
    }
}

/// Randomized adversarial sweep: on thousands of profiles the DP never
/// loses to any competitor and never beats the brute-force optimum.
#[test]
fn randomized_cross_validation_sweep() {
    let mut rng = Rng::new(99);
    for _ in 0..500 {
        let depth = rng.range(2, 11);
        let params = dynacomm::sim::workload::WorkloadParams {
            comm_mu: rng.range_f64(-1.0, 2.0),
            comp_mu: rng.range_f64(-1.0, 2.0),
            sigma: rng.range_f64(0.2, 2.0),
            delta_t: rng.range_f64(0.0, 30.0),
        };
        let cv = dynacomm::sim::workload::generate(&mut rng, depth, params);
        let (_, best) = bruteforce::forward(&cv);
        let dyna = sched::eval_forward(&cv, &sched::dynacomm::forward(&cv)).total;
        assert!((dyna - best).abs() < 1e-7, "fwd suboptimal: {cv:?}");
        let ib = sched::eval_forward(&cv, &sched::ibatch::forward(&cv)).total;
        let lbl =
            sched::eval_forward(&cv, &Decomposition::layer_by_layer(depth)).total;
        let seq = sched::eval_forward(&cv, &Decomposition::sequential(depth)).total;
        assert!(dyna <= ib + 1e-7 && dyna <= lbl + 1e-7 && dyna <= seq + 1e-7);

        let (_, best_b) = bruteforce::backward(&cv);
        let dyna_b = sched::eval_backward(&cv, &sched::dynacomm::backward(&cv)).total;
        assert!((dyna_b - best_b).abs() < 1e-7, "bwd suboptimal: {cv:?}");
    }
}

/// Scheduling decisions must be pure functions of the cost vectors for
/// fresh schedulers (statefulness only ever *reuses* earlier decisions).
#[test]
fn plans_deterministic_across_fresh_schedulers() {
    let cfg = SystemConfig::default();
    for model in models::paper_models() {
        let cv = model.cost_vectors(&cfg);
        for s in Strategy::ALL {
            let a = registry::create_for(s).plan(&cv);
            let b = registry::create_for(s).plan(&cv);
            assert_eq!(a.plan, b.plan, "{} {}", model.name, s.name());
            assert_eq!(a.predicted_fwd_ms, b.predicted_fwd_ms);
            assert_eq!(a.predicted_bwd_ms, b.predicted_bwd_ms);
        }
    }
}

/// Trait conformance over every registry entry: on random cost vectors
/// each scheduler must return decompositions that partition the layers,
/// predictions that match the independent timeline evaluator, and DynaComm
/// must beat-or-tie the fixed strategies.
#[test]
fn registry_conformance_on_random_profiles() {
    let mut rng = Rng::new(181);
    for _ in 0..60 {
        let depth = rng.range(1, 12);
        let params = dynacomm::sim::workload::WorkloadParams {
            comm_mu: rng.range_f64(-1.0, 2.0),
            comp_mu: rng.range_f64(-1.0, 2.0),
            sigma: rng.range_f64(0.2, 1.5),
            delta_t: rng.range_f64(0.0, 20.0),
        };
        let cv = dynacomm::sim::workload::generate(&mut rng, depth, params);
        let mut by_name = std::collections::HashMap::new();
        for name in registry::NAMES {
            let mut s = registry::create(name).unwrap();
            assert_eq!(s.name(), name);
            let sp = s.plan(&cv);
            // Decompositions partition the layers in both passes.
            for d in [&sp.plan.fwd, &sp.plan.bwd] {
                assert_eq!(d.depth(), depth, "{name}");
                let mut covered: Vec<usize> =
                    d.fwd_segments().iter().flat_map(|&(a, b)| a..=b).collect();
                covered.sort_unstable();
                assert_eq!(covered, (1..=depth).collect::<Vec<_>>(), "{name}");
            }
            // Predictions match the independent evaluator.
            let f = sched::eval_forward(&cv, &sp.plan.fwd).total;
            let b = sched::eval_backward(&cv, &sp.plan.bwd).total;
            assert!((sp.predicted_fwd_ms - f).abs() < 1e-7, "{name}: {sp:?}");
            assert!((sp.predicted_bwd_ms - b).abs() < 1e-7, "{name}: {sp:?}");
            assert!(!sp.reused, "{name}: fresh scheduler reused");
            by_name.insert(name, sp);
        }
        // DynaComm beats-or-ties Sequential and LBL (and the oracle
        // confirms it at these depths).
        let dyna = by_name["dynacomm"].predicted_ms();
        for fixed in ["sequential", "lbl", "ibatch", "slicing"] {
            assert!(
                dyna <= by_name[fixed].predicted_ms() + 1e-7,
                "dynacomm {dyna} lost to {fixed} {}",
                by_name[fixed].predicted_ms()
            );
        }
        assert!((dyna - by_name["bruteforce"].predicted_ms()).abs() < 1e-7);
    }
}

/// The gain-threshold property pair: threshold 0 re-plans every call and
/// matches the stateless DP exactly; a huge threshold reuses the cached
/// plan from the second call on.
#[test]
fn gain_threshold_replan_vs_reuse() {
    let mut rng = Rng::new(182);
    let depth = 14;
    let profiles: Vec<sched::CostVectors> = (0..12)
        .map(|_| {
            dynacomm::sim::workload::generate(
                &mut rng,
                depth,
                dynacomm::sim::workload::WorkloadParams::default(),
            )
        })
        .collect();

    let mut zero = registry::create_with(
        "dynacomm",
        registry::SchedulerParams { gain_threshold_ms: 0.0, ..Default::default() },
    )
    .unwrap();
    for cv in &profiles {
        let sp = zero.plan(cv);
        assert!(!sp.reused, "threshold 0 must always re-plan");
        assert_eq!(sp.plan.fwd, sched::dynacomm::forward(cv));
        assert_eq!(sp.plan.bwd, sched::dynacomm::backward(cv));
    }

    let mut huge = registry::create_with(
        "dynacomm",
        registry::SchedulerParams {
            gain_threshold_ms: f64::INFINITY,
            ..Default::default()
        },
    )
    .unwrap();
    let first = huge.plan(&profiles[0]);
    assert!(!first.reused);
    for cv in &profiles[1..] {
        let sp = huge.plan(cv);
        assert!(sp.reused, "huge threshold must reuse the cached plan");
        assert_eq!(sp.plan, first.plan);
        // Even reused, the prediction reflects the *current* costs.
        let f = sched::eval_forward(cv, &sp.plan.fwd).total;
        assert!((sp.predicted_fwd_ms - f).abs() < 1e-9);
    }
}

/// The paper's Fig. 12 claim, verified empirically: DynaComm's scheduling
/// wall-clock grows as ~L^3.
#[test]
fn dp_complexity_is_cubic() {
    let depths = [40usize, 80, 160, 320];
    let mut times = Vec::new();
    let mut rng = Rng::new(7);
    for &d in &depths {
        let cv = dynacomm::sim::workload::generate(
            &mut rng,
            d,
            dynacomm::sim::workload::WorkloadParams::default(),
        );
        // Warm-up + best-of-3 to de-noise.
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            std::hint::black_box(sched::dynacomm::forward(&cv));
            best = best.min(t0.elapsed().as_secs_f64());
        }
        times.push(best);
    }
    let k = dynacomm::util::stats::power_law_exponent(
        &depths.iter().map(|&d| d as f64).collect::<Vec<_>>(),
        &times,
    );
    assert!((2.0..4.0).contains(&k), "measured exponent {k} (times {times:?})");
}
