//! Elastic-fleet churn tests (`docs/FAULTS.md`): the tiered fleet must
//! survive workers and an aggregator dying and respawning **mid-run**, a
//! killed shard must restore byte-identically from its checkpoint, and
//! the fault-injection proxy (`net::fault`) must replay the exact same
//! schedule for the same seed.
//!
//! The model is `sync_integration`'s distributed least-squares problem
//! (`min_w ‖w − target‖²`) over raw registered connections — every push
//! strictly contracts every coordinate toward the target, so per-worker
//! loss must strictly decrease across **every snapshot advance**, churn
//! or not. Replies are deduplicated by their `applied` clock before that
//! assertion: during a failover the surviving group may legitimately
//! outrun a rejoiner by whole rounds, so two consecutive pulls can see
//! the same snapshot (equal loss, asserted equal), but a *fresher*
//! snapshot must always mean strictly lower loss — and the snapshot
//! clock must never rewind.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dynacomm::net::codec::CodecId;
use dynacomm::net::fault::{Dir, FaultEvent, FaultProxy, FaultSpec};
use dynacomm::net::{slab, Connection, Message, PROTOCOL_VERSION};
use dynacomm::ps::sync::SyncConfig;
use dynacomm::ps::{
    AggConfig, Checkpoint, ParamServer, RegionalAggregator, ServerConfig, ServerOptions,
};

/// Crosses an int8 chunk boundary (CHUNK = 1024), like `sync_integration`.
const ELEMS: usize = 1500;
const GROUPS: usize = 2;
const GROUP_SIZE: usize = 4;
const WORKERS: usize = GROUPS * GROUP_SIZE;
const ITERS: u64 = 14;
const LR: f32 = 0.1;
/// The worker victims die right after completing this iteration.
const WORKER_KILL_AFTER: u64 = 4;
/// The aggregator victim dies once every worker has completed this many.
const AGG_KILL_AFTER: u64 = 8;

fn target(j: usize) -> f32 {
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn loss_of(w: &[f32]) -> f32 {
    w.iter().enumerate().map(|(j, v)| (v - target(j)).powi(2)).sum::<f32>()
        / w.len() as f32
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Fallible registration: version handshake only (BSP default needs no
/// sync agreement). Dialing a dead peer or a closing listener errors —
/// the caller retries against the currently published address.
fn try_register(addr: SocketAddr, worker: u32) -> anyhow::Result<Connection> {
    let mut conn = Connection::new(TcpStream::connect(addr)?, None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION })?;
    match conn.recv()? {
        Message::HelloAck { version, .. } => {
            anyhow::ensure!(version == PROTOCOL_VERSION, "version mismatch");
        }
        m => anyhow::bail!("bad hello ack: {m:?}"),
    }
    Ok(conn)
}

/// Register against whatever address the harness currently publishes for
/// this group, retrying until the (re)spawned peer accepts.
fn register_current(addrs: &Mutex<Vec<SocketAddr>>, group: usize, worker: u32) -> Connection {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let addr = addrs.lock().unwrap()[group];
        match try_register(addr, worker) {
            Ok(conn) => return conn,
            Err(_) => {
                assert!(
                    Instant::now() < deadline,
                    "worker {worker} could not rejoin group {group}"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// One fallible least-squares iteration: pull, measure loss, push the
/// exact gradient. Any wire error (the peer died mid-step) surfaces to
/// the caller, which reconnects and retries the same iteration.
fn try_step(conn: &mut Connection, iter: u64) -> anyhow::Result<(u64, f32)> {
    conn.send(&Message::Pull { iter, lo: 0, hi: 0 })?;
    let (applied, data) = match conn.recv()? {
        Message::PullReply { applied, data, .. } => (applied, data),
        m => anyhow::bail!("bad pull reply: {m:?}"),
    };
    let w = slab::to_f32s(&data);
    let loss = loss_of(&w);
    let grad: Vec<f32> =
        w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
    conn.send(&Message::Push {
        iter,
        lo: 0,
        hi: 0,
        codec: CodecId::Fp32,
        data: slab::from_f32s(&grad),
    })?;
    match conn.recv()? {
        Message::PushAck { .. } => Ok((applied, loss)),
        m => anyhow::bail!("bad push ack: {m:?}"),
    }
}

fn start_agg(group: u32, shard_addr: SocketAddr) -> RegionalAggregator {
    RegionalAggregator::start(AggConfig {
        group,
        workers: GROUP_SIZE as u32,
        upstream_addrs: vec![shard_addr],
        layer_elems: vec![ELEMS],
        downstream_sync: SyncConfig::default(),
        upstream_sync: SyncConfig::default(),
        upstream_codec: CodecId::Fp32,
        handler_threads: GROUP_SIZE + 2,
        io_timeout_ms: 0,
    })
    .unwrap()
}

/// Per-worker acceptance: the snapshot clock never rewinds; equal clocks
/// mean byte-identical parameters (equal loss); a fresher clock means
/// strictly lower loss; enough distinct snapshots were observed to call
/// it progress; and the run ends far below where it started.
fn assert_curve(w: usize, curve: &[(u64, f32)], initial: f32) {
    assert_eq!(curve.len(), ITERS as usize, "worker {w} skipped iterations");
    let mut distinct = 1usize;
    for k in 1..curve.len() {
        let (pa, pl) = curve[k - 1];
        let (a, l) = curve[k];
        assert!(a >= pa, "worker {w}: snapshot clock rewound {pa} -> {a}");
        if a == pa {
            assert_eq!(l, pl, "worker {w}: same snapshot {a}, different loss");
        } else {
            distinct += 1;
            assert!(
                l < pl,
                "worker {w}: snapshot advanced {pa} -> {a} but loss did not \
                 strictly decrease: {pl} -> {l}"
            );
        }
    }
    assert!(
        distinct >= ITERS as usize / 2,
        "worker {w} observed only {distinct} distinct snapshots over {ITERS} iters"
    );
    let last = curve[curve.len() - 1].1;
    assert!(
        last < 0.25 * initial,
        "worker {w} not enough progress: {last} vs initial {initial}"
    );
}

/// The flagship churn run: 8 workers in 2 groups behind regional
/// aggregators against one BSP cloud shard. Two workers (one per group)
/// die after completing iteration 4 and rejoin — adopting the tier
/// snapshot on the way back in — and one whole aggregator is killed and
/// replaced (fresh group identity) once every worker has finished
/// iteration 8. Nobody stalls, every curve converges.
#[test]
fn fleet_survives_worker_and_aggregator_churn() {
    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; ELEMS]);
    let srv = ParamServer::start_with(
        ServerConfig { workers: WORKERS, lr: LR },
        layers,
        None,
        ServerOptions::default(),
    )
    .unwrap();
    let shard_addr = srv.handle().addr;
    let mut aggs = vec![start_agg(101, shard_addr), start_agg(102, shard_addr)];
    let addrs: Arc<Mutex<Vec<SocketAddr>>> =
        Arc::new(Mutex::new(aggs.iter().map(|a| a.addr()).collect()));
    let done: Arc<Vec<AtomicU64>> =
        Arc::new((0..WORKERS).map(|_| AtomicU64::new(0)).collect());
    let initial = loss_of(&vec![0.0f32; ELEMS]);

    let threads: Vec<_> = (0..WORKERS)
        .map(|w| {
            let addrs = addrs.clone();
            let done = done.clone();
            // One victim per group: worker 2 (group 0) and worker 5
            // (group 1) self-kill after completing WORKER_KILL_AFTER.
            let kill_after = (w == 2 || w == 5).then_some(WORKER_KILL_AFTER);
            std::thread::Builder::new()
                .name(format!("churn-worker-{w}"))
                .spawn(move || {
                    let group = w / GROUP_SIZE;
                    let mut conn = register_current(&addrs, group, w as u32);
                    let mut curve: Vec<(u64, f32)> = Vec::new();
                    let mut iter = 0u64;
                    while iter < ITERS {
                        match try_step(&mut conn, iter) {
                            Ok((applied, loss)) => {
                                curve.push((applied, loss));
                                done[w].store(iter + 1, Ordering::SeqCst);
                                if kill_after == Some(iter) {
                                    // Die between iterations: dropping the
                                    // session closes the socket, the
                                    // aggregator's handler sees EOF and
                                    // departs the identity. (Mid-frame
                                    // deaths are `net::fault`'s job.)
                                    drop(conn);
                                    std::thread::sleep(Duration::from_millis(5));
                                    // …and rejoin mid-run, adopting the
                                    // tier snapshot before training on.
                                    conn = register_current(&addrs, group, w as u32);
                                    conn.send(&Message::SnapshotReq { lo: 0, hi: 0 })
                                        .unwrap();
                                    match conn.recv().unwrap() {
                                        Message::SnapshotReply {
                                            workers, data, ..
                                        } => {
                                            assert_eq!(workers, GROUP_SIZE as u32);
                                            let snap = slab::to_f32s(&data);
                                            assert_eq!(snap.len(), ELEMS);
                                            assert!(
                                                loss_of(&snap) < curve[0].1,
                                                "adopted snapshot no fresher \
                                                 than the starting parameters"
                                            );
                                        }
                                        m => panic!("bad snapshot reply: {m:?}"),
                                    }
                                }
                                iter += 1;
                            }
                            Err(_) => {
                                // The peer died mid-step (the aggregator
                                // failover): rejoin and retry this iter.
                                conn = register_current(&addrs, group, w as u32);
                            }
                        }
                    }
                    curve
                })
                .unwrap()
        })
        .collect();

    // Aggregator failover: once the whole fleet is past AGG_KILL_AFTER,
    // kill group 1's aggregator and replace it under a fresh group
    // identity — the shard's elastic registry re-arms the departed
    // barrier weight when the replacement registers.
    wait_until("the fleet to reach the failover point", || {
        done.iter().all(|d| d.load(Ordering::SeqCst) >= AGG_KILL_AFTER)
    });
    let dead = aggs.remove(1);
    drop(dead); // severs both hops: downstream recvs and upstream sessions
    let replacement = start_agg(103, shard_addr);
    addrs.lock().unwrap()[1] = replacement.addr();
    aggs.push(replacement);

    let curves: Vec<Vec<(u64, f32)>> =
        threads.into_iter().map(|t| t.join().unwrap()).collect();
    for (w, curve) in curves.iter().enumerate() {
        assert_curve(w, curve, initial);
    }
    drop(aggs);
    drop(srv);
}

/// Kill a shard, restore it from its checkpoint, and resume: the restored
/// state must be **byte-identical slab-for-slab** (asserted by
/// re-checkpointing the restored shard and comparing whole files — slabs,
/// versions, and worker clocks in one shot) and training must continue
/// exactly where it stopped, losses still strictly decreasing.
#[test]
fn killed_shard_restores_byte_identical_and_resumes() {
    const SMALL: usize = 256;
    const FLEET: usize = 2;
    let dir = std::env::temp_dir()
        .join(format!("dynacomm-churn-restore-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("shard-0.ckpt");
    let path2 = dir.join("shard-0.rewrite.ckpt");

    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; SMALL]);
    let cfg = ServerConfig { workers: FLEET, lr: LR };
    let mut srv =
        ParamServer::start_with(cfg, layers, None, ServerOptions::default()).unwrap();
    let addr = srv.handle().addr;

    let small_target = |j: usize| target(j);
    let small_loss = |w: &[f32]| -> f32 {
        w.iter().enumerate().map(|(j, v)| (v - small_target(j)).powi(2)).sum::<f32>()
            / w.len() as f32
    };
    // Drive both BSP workers from one thread: all pulls for an iteration,
    // then all pushes — the barrier only ever parks pulls.
    let mut conns: Vec<Connection> =
        (0..FLEET as u32).map(|w| try_register(addr, w).unwrap()).collect();
    let mut losses: Vec<Vec<f32>> = vec![Vec::new(); FLEET];
    let mut run = |conns: &mut Vec<Connection>,
                   losses: &mut Vec<Vec<f32>>,
                   iters: std::ops::Range<u64>| {
        for iter in iters {
            let mut grads: Vec<Vec<f32>> = Vec::new();
            for (w, conn) in conns.iter_mut().enumerate() {
                conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
                let data = match conn.recv().unwrap() {
                    Message::PullReply { applied, data, .. } => {
                        assert_eq!(applied, iter, "BSP lockstep");
                        data
                    }
                    m => panic!("{m:?}"),
                };
                let v = slab::to_f32s(&data);
                losses[w].push(small_loss(&v));
                grads.push(
                    v.iter()
                        .enumerate()
                        .map(|(j, x)| 2.0 * (x - small_target(j)))
                        .collect(),
                );
            }
            for (conn, grad) in conns.iter_mut().zip(&grads) {
                conn.send(&Message::Push {
                    iter,
                    lo: 0,
                    hi: 0,
                    codec: CodecId::Fp32,
                    data: slab::from_f32s(grad),
                })
                .unwrap();
                assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
            }
        }
    };
    run(&mut conns, &mut losses, 0..3);

    // Checkpoint, then kill the shard with its sessions still open.
    srv.write_checkpoint(&path).unwrap();
    let before = srv.snapshot(0).unwrap();
    drop(conns);
    srv.shutdown();
    drop(srv);

    // Restore and prove byte identity: a fresh checkpoint of the restored
    // shard must reproduce the original file exactly.
    let ck = Checkpoint::read_from(&path).unwrap();
    let srv =
        ParamServer::start_restored(cfg, None, ServerOptions::default(), &ck).unwrap();
    assert_eq!(srv.snapshot(0).unwrap(), before, "restored parameters differ");
    srv.write_checkpoint(&path2).unwrap();
    assert_eq!(
        std::fs::read(&path).unwrap(),
        std::fs::read(&path2).unwrap(),
        "restored shard did not re-checkpoint byte-identically"
    );

    // Resume exactly where the fleet stopped: same worker ids, next
    // iteration, losses still strictly decreasing across the kill.
    let addr = srv.handle().addr;
    let mut conns: Vec<Connection> =
        (0..FLEET as u32).map(|w| try_register(addr, w).unwrap()).collect();
    run(&mut conns, &mut losses, 3..6);
    for (w, curve) in losses.iter().enumerate() {
        assert_eq!(curve.len(), 6);
        for k in 1..curve.len() {
            assert!(
                curve[k] < curve[k - 1],
                "worker {w} loss did not strictly decrease across the \
                 restore at iter {k}: {curve:?}"
            );
        }
    }
    drop(conns);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fault proxy's schedule is a pure function of the seed: the same
/// seeded run produces the exact same event log twice, and that log
/// matches the schedule computed *offline* from [`FaultSpec::decide`]
/// over the session's known frame sequence.
#[test]
fn fault_schedule_is_deterministic_across_runs() {
    const SMALL: usize = 64;
    const RUN_ITERS: u64 = 5;
    let spec = FaultSpec {
        seed: 42,
        delay_p: 0.5,
        delay_max_ms: 2,
        ..FaultSpec::default()
    };

    let run = |spec: &FaultSpec| -> (Vec<f32>, Vec<FaultEvent>) {
        let mut layers = HashMap::new();
        layers.insert(0, vec![0.0f32; SMALL]);
        let srv = ParamServer::start_with(
            ServerConfig { workers: 1, lr: LR },
            layers,
            None,
            ServerOptions::default(),
        )
        .unwrap();
        let mut proxy = FaultProxy::start(srv.handle().addr, spec.clone()).unwrap();
        let mut conn = try_register(proxy.addr(), 0).unwrap();
        let mut losses = Vec::new();
        for iter in 0..RUN_ITERS {
            let (applied, loss) = try_step(&mut conn, iter).unwrap();
            assert_eq!(applied, iter, "single-worker BSP is lockstep");
            losses.push(loss);
        }
        drop(conn);
        let events = proxy.events();
        proxy.shutdown();
        drop(srv);
        (losses, events)
    };

    let (losses_a, events_a) = run(&spec);
    let (losses_b, events_b) = run(&spec);
    assert_eq!(events_a, events_b, "same seed must replay the same schedule");
    assert_eq!(losses_a, losses_b, "training under replayed faults must agree");
    for k in 1..losses_a.len() {
        assert!(losses_a[k] < losses_a[k - 1], "loss must still converge");
    }

    // The observed log must equal the schedule computed offline from the
    // session's deterministic frame sequence: up = Hello, then
    // (Pull, Push) per iteration; down = HelloAck, then
    // (PullReply, PushAck).
    let mut expected = Vec::new();
    let mut sequence = |dir: Dir, opcodes: Vec<u8>| {
        for (frame, opcode) in opcodes.into_iter().enumerate() {
            let action = spec.decide(0, dir, frame as u64, opcode);
            if action != dynacomm::net::fault::FaultAction::Pass {
                expected.push(FaultEvent { conn: 0, dir, frame: frame as u64, opcode, action });
            }
        }
    };
    let mut up = vec![5u8];
    let mut down = vec![6u8];
    for _ in 0..RUN_ITERS {
        up.extend([1u8, 3]);
        down.extend([2u8, 4]);
    }
    sequence(Dir::Up, up);
    sequence(Dir::Down, down);
    expected.sort_by_key(|e| (e.conn, e.dir, e.frame));
    assert_eq!(events_a, expected, "observed log diverged from the pure schedule");
}
