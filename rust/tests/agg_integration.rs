//! End-to-end hierarchical-tier tests over the real wire path
//! (`ps::agg`, `docs/TOPOLOGY.md`): two regional aggregators, four edge
//! workers each, against two cloud shards — with a *different* codec on
//! each hop (int8 edge→regional, fp16 regional→cloud).
//!
//! The model is `sync_integration`'s distributed least-squares problem
//! (`min_w ‖w − target‖²`), split across two layers so the round-robin
//! shard striping is actually exercised: the aggregator must stitch each
//! shared downstream reply from both shards' sub-replies and route each
//! layer's combined push to its owning shard. The acceptance properties:
//!
//! * per-worker strictly decreasing loss, final loss far below initial —
//!   through two codec conversions (cloud fp32 → fp16 → int8 on the pull
//!   path, int8 → fp32-sum → fp16 on the push path);
//! * BSP lockstep end to end: every reply's `applied` equals the
//!   requested iteration, across both hops;
//! * fan-in arithmetic: the cloud's ingress counters see one combined
//!   push per layer per iteration, not one per worker.

use std::collections::HashMap;
use std::net::TcpStream;

use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message, PROTOCOL_VERSION};
use dynacomm::ps::sync::{SyncConfig, SyncMode};
use dynacomm::ps::{AggConfig, ParamServer, RegionalAggregator, ServerConfig};

/// Two layers, striped over two shards (layer 0 → shard 0, layer 1 →
/// shard 1). Uneven sizes so a stitching bug cannot cancel out.
const LAYER_ELEMS: [usize; 2] = [600, 300];
const GROUPS: usize = 2;
const GROUP_SIZE: usize = 4;
const WORKERS: usize = GROUPS * GROUP_SIZE;
const ITERS: u64 = 12;
const LR: f32 = 0.1;

fn target(j: usize) -> f32 {
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn loss_of(w: &[f32]) -> f32 {
    w.iter().enumerate().map(|(j, v)| (v - target(j)).powi(2)).sum::<f32>()
        / w.len() as f32
}

/// Boot the full tiered fleet: 2 cloud shards (BSP, expecting the total
/// fleet), 2 regional aggregators (BSP downstream, BSP + fp16 upstream).
fn start_tier() -> (Vec<ParamServer>, Vec<RegionalAggregator>) {
    let shards: Vec<ParamServer> = (0..2)
        .map(|s| {
            // Shard `s` owns layer `s` (round-robin over 2 layers).
            let mut layers = HashMap::new();
            layers.insert(s, vec![0.0f32; LAYER_ELEMS[s]]);
            ParamServer::start(ServerConfig { workers: WORKERS, lr: LR }, layers, None)
                .unwrap()
        })
        .collect();
    let upstream_addrs: Vec<_> = shards.iter().map(|s| s.handle().addr).collect();
    let aggs = (0..GROUPS)
        .map(|g| {
            RegionalAggregator::start(AggConfig {
                // Group ids live past the worker-id space.
                group: 100 + g as u32,
                workers: GROUP_SIZE as u32,
                upstream_addrs: upstream_addrs.clone(),
                layer_elems: LAYER_ELEMS.to_vec(),
                downstream_sync: SyncConfig::default(),
                upstream_sync: SyncConfig::default(),
                upstream_codec: CodecId::Fp16,
                handler_threads: GROUP_SIZE + 2,
                io_timeout_ms: 0,
            })
            .unwrap()
        })
        .collect();
    (shards, aggs)
}

/// Register an edge session at its aggregator: version handshake, BSP
/// sync agreement, int8 codec negotiation.
fn register(addr: std::net::SocketAddr, worker: u32) -> Connection {
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
    match conn.recv().unwrap() {
        Message::HelloAck { workers, version } => {
            assert_eq!(version, PROTOCOL_VERSION);
            assert_eq!(workers, GROUP_SIZE as u32, "the aggregator fronts the group");
        }
        m => panic!("{m:?}"),
    }
    conn.send(&Message::SyncPropose { mode: SyncMode::Bsp, bound: 0 }).unwrap();
    match conn.recv().unwrap() {
        Message::SyncAgree { mode, .. } => assert_eq!(mode, SyncMode::Bsp),
        m => panic!("{m:?}"),
    }
    conn.send(&Message::CodecPropose { pref: CodecId::Int8 }).unwrap();
    match conn.recv().unwrap() {
        Message::CodecAgree { codec } => assert_eq!(codec, CodecId::Int8),
        m => panic!("{m:?}"),
    }
    conn
}

/// One tiered train step: pull both layers through the aggregator (one
/// int8 reply stitched from both shards), measure loss, push the exact
/// gradient int8-encoded per layer. Returns (applied, loss).
fn train_step(conn: &mut Connection, iter: u64) -> (u64, f32) {
    let wc = CodecId::Int8.codec();
    conn.send(&Message::Pull { iter, lo: 0, hi: 1 }).unwrap();
    let (applied, data) = match conn.recv().unwrap() {
        Message::PullReply { applied, codec, data, .. } => {
            assert_eq!(codec, CodecId::Int8, "downstream hop speaks int8");
            (applied, data)
        }
        m => panic!("{m:?}"),
    };
    // Per-layer int8 chunks, ascending: decode into one flat w.
    let split = wc.wire_len(slab::ELEM * LAYER_ELEMS[0]);
    assert_eq!(data.len(), split + wc.wire_len(slab::ELEM * LAYER_ELEMS[1]));
    let mut raw = Vec::new();
    wc.decode(&data[..split], &mut raw).unwrap();
    wc.decode(&data[split..], &mut raw).unwrap();
    let w = slab::to_f32s(&raw);
    let loss = loss_of(&w);
    let grad: Vec<f32> =
        w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
    let mut wire = Vec::new();
    wc.encode(&slab::from_f32s(&grad[..LAYER_ELEMS[0]]), &mut wire);
    wc.encode(&slab::from_f32s(&grad[LAYER_ELEMS[0]..]), &mut wire);
    conn.send(&Message::Push { iter, lo: 0, hi: 1, codec: CodecId::Int8, data: wire })
        .unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    (applied, loss)
}

/// The tiered acceptance test: 2 aggregators × 4 workers × 2 shards with
/// mixed per-hop codecs converge in BSP lockstep, and the cloud sees the
/// group-combined traffic, not the per-worker traffic.
#[test]
fn tiered_training_converges_with_mixed_per_hop_codecs() {
    let (shards, aggs) = start_tier();
    let threads: Vec<_> = (0..WORKERS as u32)
        .map(|w| {
            let agg_addr = aggs[w as usize / GROUP_SIZE].addr();
            std::thread::spawn(move || {
                let mut conn = register(agg_addr, w);
                let mut losses = Vec::with_capacity(ITERS as usize);
                for iter in 0..ITERS {
                    let (applied, loss) = train_step(&mut conn, iter);
                    assert_eq!(applied, iter, "worker {w}: BSP lockstep broken");
                    losses.push(loss);
                }
                losses
            })
        })
        .collect();
    let curves: Vec<Vec<f32>> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    for (w, losses) in curves.iter().enumerate() {
        assert_eq!(losses.len(), ITERS as usize);
        for k in 1..losses.len() {
            assert!(
                losses[k] < losses[k - 1],
                "worker {w} loss did not strictly decrease at iter {k}: {losses:?}"
            );
        }
        assert!(
            losses[losses.len() - 1] < 0.2 * losses[0],
            "worker {w} not enough progress: {losses:?}"
        );
    }
    // The barrier makes every worker's curve identical — across groups
    // too, since both hops run BSP.
    for c in &curves[1..] {
        assert_eq!(c, &curves[0], "workers diverged under tiered BSP");
    }
    // Fan-in arithmetic at the cloud boundary: each shard's ingress is
    // GROUPS combined fp16 pushes per iteration of its one owned layer —
    // a flat fleet would have sent WORKERS pushes instead (4× the bytes).
    for (s, shard) in shards.iter().enumerate() {
        let per_push = CodecId::Fp16.wire_len(slab::ELEM * LAYER_ELEMS[s]) as u64;
        assert_eq!(
            shard.wire_stats().ingress_bytes,
            ITERS * GROUPS as u64 * per_push,
            "shard {s}: cloud ingress must be per-group, not per-worker"
        );
    }
    // Each aggregator assembled one shared reply per iteration and served
    // the other three group members from it.
    for (g, agg) in aggs.iter().enumerate() {
        let st = agg.stats();
        assert_eq!(st.reply_cache_builds, ITERS, "group {g}: one upstream round/iter");
        assert_eq!(
            st.reply_cache_hits,
            ITERS * (GROUP_SIZE as u64 - 1),
            "group {g}: the rest of the group must share the assembly"
        );
        assert_eq!(st.forwarded_pushes, ITERS * 2, "group {g}: one push per layer/iter");
    }
}
