//! End-to-end observability-plane tests (`obs::{registry, trace, expo}`).
//!
//! Same socket-level harness as `sync_integration`: a distributed
//! least-squares problem trained through a real loopback [`ParamServer`]
//! — no PJRT artifacts needed — but here the subject is the telemetry,
//! not the math:
//!
//! * the Prometheus exposition is well-formed line-by-line (property
//!   test over a live scrape);
//! * the Chrome trace export is valid JSON with balanced `B`/`E` events
//!   and per-thread monotone timestamps (golden-shape test);
//! * span rings drop **oldest** at capacity;
//! * steady state allocates nothing even with tracing armed (the pool
//!   allocation counter goes flat while spans keep recording);
//! * the `obs-e2e` scenario CI runs: scrape a training run mid-flight,
//!   assert the key series are present and increasing, and export a
//!   trace (`results/obs_trace.json`) in which pull spans overlap
//!   compute spans on different threads.
//!
//! Obs registrations here go through the `register_*` functions, not the
//! `obs_counter!` macros: dynalint's `metrics` check holds macro sites
//! (production registrations) to the documented catalog, and these are
//! deliberately test-scoped scratch series.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message, PROTOCOL_VERSION};
use dynacomm::obs;
use dynacomm::obs::expo::{scrape, MetricsServer};
use dynacomm::obs::trace;
use dynacomm::ps::worker::record_overlap_drift;
use dynacomm::ps::{ParamServer, ServerConfig, ServerOptions};
use dynacomm::util::json::Json;

const ELEMS: usize = 1500;
const LR: f32 = 0.1;

fn target(j: usize) -> f32 {
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn start_server(workers: usize) -> ParamServer {
    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; ELEMS]);
    ParamServer::start_with(
        ServerConfig { workers, lr: LR },
        layers,
        None,
        ServerOptions::default(),
    )
    .unwrap()
}

fn register(addr: std::net::SocketAddr, worker: u32) -> Connection {
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
    match conn.recv().unwrap() {
        Message::HelloAck { version, .. } => assert_eq!(version, PROTOCOL_VERSION),
        m => panic!("{m:?}"),
    }
    conn
}

/// One pull + push round trip of the least-squares worker.
fn train_step(conn: &mut Connection, iter: u64) {
    conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
    let data = match conn.recv().unwrap() {
        Message::PullReply { data, .. } => data,
        m => panic!("{m:?}"),
    };
    let w = slab::to_f32s(&data);
    let grad: Vec<f32> =
        w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
    conn.send(&Message::Push {
        iter,
        lo: 0,
        hi: 0,
        codec: CodecId::Fp32,
        data: slab::from_f32s(&grad),
    })
    .unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
}

/// The total of a series across instances as read from a scrape body,
/// summing every sample line whose name part is exactly `name`.
fn scraped_total(body: &str, name: &str) -> Option<f64> {
    let mut total = 0.0;
    let mut hit = false;
    for line in body.lines() {
        if line.starts_with('#') {
            continue;
        }
        let series = line.split(['{', ' ']).next().unwrap_or("");
        if series != name {
            continue;
        }
        let value: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        total += value;
        hit = true;
    }
    hit.then_some(total)
}

/// Property: every line of a live scrape is either a `# TYPE name kind`
/// comment with a known kind, or a `name{labels} value` sample whose
/// value parses as a finite f64 and whose label fragment carries the
/// automatic `inst=` tag.
#[test]
fn exposition_format_is_wellformed_line_by_line() {
    let c = obs::register_counter("obstest_expo_events_total", "", obs::next_inst());
    c.add(7);
    let g = obs::register_gauge("obstest_expo_depth", "shard=\"0\"", obs::next_inst());
    g.set(-2.5);
    let h = obs::register_histogram("obstest_expo_lat_ms", "", obs::next_inst());
    for v in [0.02, 1.0, 300.0, 7e6] {
        h.observe(v);
    }

    let mut srv = MetricsServer::bind("127.0.0.1:0").unwrap();
    let body = scrape(srv.addr()).unwrap();
    srv.shutdown();

    assert!(!body.is_empty());
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap();
            let kind = it.next().unwrap();
            assert!(it.next().is_none(), "trailing junk in TYPE line: {line}");
            assert!(!name.is_empty());
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown kind in {line}"
            );
            continue;
        }
        // Sample line: name{labels} value
        let (series, value) = line.rsplit_once(' ').expect(line);
        let v: f64 = value.parse().expect(line);
        assert!(v.is_finite(), "non-finite sample: {line}");
        let (name, labels) = series.split_once('{').expect(line);
        assert!(!name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'));
        let labels = labels.strip_suffix('}').expect(line);
        assert!(
            labels.split(',').any(|kv| kv.starts_with("inst=")),
            "missing automatic inst label: {line}"
        );
        samples += 1;
    }
    assert!(samples >= 3, "scrape carried our series:\n{body}");

    // Our registered values actually round-tripped.
    assert_eq!(scraped_total(&body, "obstest_expo_events_total"), Some(7.0));
    assert_eq!(scraped_total(&body, "obstest_expo_depth"), Some(-2.5));
    assert_eq!(scraped_total(&body, "obstest_expo_lat_ms_count"), Some(4.0));
    assert!(body.contains("obstest_expo_lat_ms_bucket"));
    assert!(body.contains("le=\"+Inf\""));
}

/// Golden-shape test for the Chrome trace export: parses as JSON, every
/// event is `B`/`E`/`M`, `B` and `E` balance per `(tid, name)`, and each
/// thread's timeline is monotone in `ts`.
#[test]
fn chrome_trace_export_is_valid_balanced_and_monotone() {
    trace::set_enabled(true);
    let gate = Arc::new(Barrier::new(2));
    let g2 = gate.clone();
    let t = std::thread::Builder::new()
        .name("obstest-golden".to_string())
        .spawn(move || {
            g2.wait();
            for _ in 0..3 {
                let _outer = trace::span(trace::SPAN_PULL_SEG);
                let _inner = trace::span(trace::SPAN_DECODE_SEG);
                std::thread::sleep(Duration::from_millis(1));
            }
        })
        .unwrap();
    gate.wait();
    for _ in 0..3 {
        let _sp = trace::span(trace::SPAN_FWD_LAYER);
        std::thread::sleep(Duration::from_millis(1));
    }
    t.join().unwrap();

    let text = trace::chrome_trace_json();
    let json = Json::parse(&text).expect("trace is valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());

    let mut balance: HashMap<(u64, String), i64> = HashMap::new();
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
        match ph {
            "M" => continue, // thread_name metadata carries no ts
            "B" | "E" => {
                assert!(
                    trace::SPAN_NAMES.contains(&name.as_str()),
                    "unknown span name {name}"
                );
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
                assert!(
                    ts >= *prev,
                    "tid {tid}: ts went backwards ({ts} after {prev})"
                );
                *prev = ts;
                *balance.entry((tid, name)).or_insert(0) +=
                    if ph == "B" { 1 } else { -1 };
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    for ((tid, name), v) in &balance {
        assert_eq!(*v, 0, "unbalanced B/E for {name} on tid {tid}");
    }
    // Our two threads' spans made it in.
    assert!(balance.keys().any(|(_, n)| n == "pull-seg"));
    assert!(balance.keys().any(|(_, n)| n == "fwd-layer"));
}

#[test]
fn span_ring_drops_oldest_at_capacity() {
    let ring = trace::Ring::new(8);
    for i in 0..20u64 {
        ring.record(trace::SPAN_FWD_LAYER, i, i + 1);
    }
    let got = ring.snapshot();
    assert_eq!(got.len(), 8, "ring holds exactly its capacity");
    let begins: Vec<u64> = got.iter().map(|(_, b, _)| *b).collect();
    assert_eq!(begins, (12..20).collect::<Vec<u64>>(), "newest retained, oldest first");
}

/// The headline zero-alloc claim with the obs plane fully armed: after
/// warm-up, further pull/push iterations allocate nothing — the pool
/// allocation counter stays flat while tracing records spans for every
/// request the whole time.
#[test]
fn steady_state_allocates_nothing_with_tracing_enabled() {
    trace::set_enabled(true);
    let srv = start_server(1);
    let mut conn = register(srv.handle().addr, 0);
    for iter in 0..4 {
        train_step(&mut conn, iter);
    }
    let warm = srv.wire_stats();
    for iter in 4..16 {
        train_step(&mut conn, iter);
    }
    let steady = srv.wire_stats();
    assert_eq!(
        steady.pool.allocations, warm.pool.allocations,
        "steady-state iterations allocated: {:?} -> {:?}",
        warm.pool, steady.pool
    );
    assert!(
        steady.pool.recycled > warm.pool.recycled,
        "pool kept serving checkouts from the free list"
    );
    drop(conn);
    drop(srv); // Drop shuts the server down and joins its handlers.
}

/// Reconstruct `(tid, name, begin_us, end_us)` intervals from a Chrome
/// trace's `B`/`E` stream (per-tid, per-name FIFO pairing — our probe
/// spans never self-nest).
fn intervals(events: &[Json]) -> Vec<(u64, String, f64, f64)> {
    let mut open: HashMap<(u64, String), Vec<f64>> = HashMap::new();
    let mut out = Vec::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        if ph != "B" && ph != "E" {
            continue;
        }
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        if ph == "B" {
            open.entry((tid, name)).or_default().push(ts);
        } else if let Some(begin) = open.get_mut(&(tid, name.clone())).and_then(Vec::pop) {
            out.push((tid, name, begin, ts));
        }
    }
    out
}

/// The CI `obs-e2e` scenario: loopback BSP training with the scrape
/// endpoint live, two mid-run scrapes asserting the key series are
/// present and increasing, a populated overlap-drift histogram, and a
/// trace artifact in which pull spans overlap compute spans.
#[test]
fn obs_e2e_scrape_mid_run_and_trace_artifact() {
    trace::set_enabled(true);
    let srv = start_server(1);
    let mut metrics = MetricsServer::bind("127.0.0.1:0").unwrap();
    let mut conn = register(srv.handle().addr, 0);

    for iter in 0..3 {
        train_step(&mut conn, iter);
    }
    let first = scrape(metrics.addr()).unwrap();
    let pulls_1 = scraped_total(&first, "dynacomm_server_pull_replies_total")
        .expect("pull counter scraped mid-run");
    let applies_1 = scraped_total(&first, "dynacomm_server_apply_events_total")
        .expect("apply counter scraped mid-run");
    assert!(pulls_1 >= 3.0, "served pulls visible: {pulls_1}");
    assert!(applies_1 >= 3.0, "applied pushes visible: {applies_1}");
    assert!(
        scraped_total(&first, "dynacomm_net_rx_frames_total").unwrap_or(0.0) > 0.0,
        "transport counters visible"
    );

    // The overlap audit's sink, fed here exactly as EdgeWorker feeds it.
    record_overlap_drift(true, 12.0, 10.5);
    record_overlap_drift(false, 30.0, 33.0);

    for iter in 3..6 {
        train_step(&mut conn, iter);
    }
    let second = scrape(metrics.addr()).unwrap();
    let pulls_2 =
        scraped_total(&second, "dynacomm_server_pull_replies_total").unwrap();
    let applies_2 =
        scraped_total(&second, "dynacomm_server_apply_events_total").unwrap();
    assert!(pulls_2 > pulls_1, "pulls increased: {pulls_1} -> {pulls_2}");
    assert!(applies_2 > applies_1, "applies increased: {applies_1} -> {applies_2}");
    assert!(
        scraped_total(&second, "dynacomm_overlap_drift_ms_count").unwrap() >= 2.0,
        "drift histogram populated and scraped"
    );

    drop(conn);
    drop(srv);
    metrics.shutdown();

    // Worker-shaped overlap: a puller thread holds pull-seg spans while
    // this thread runs fwd-layer spans through the same wall-clock
    // window — the schedule overlap the paper is about, in trace form.
    let gate = Arc::new(Barrier::new(2));
    let g2 = gate.clone();
    let puller = std::thread::Builder::new()
        .name("obstest-puller".to_string())
        .spawn(move || {
            g2.wait();
            let _sp = trace::span(trace::SPAN_PULL_SEG);
            std::thread::sleep(Duration::from_millis(60));
        })
        .unwrap();
    gate.wait();
    std::thread::sleep(Duration::from_millis(5));
    for _ in 0..4 {
        let _sp = trace::span(trace::SPAN_FWD_LAYER);
        std::thread::sleep(Duration::from_millis(5));
    }
    puller.join().unwrap();

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/results/obs_trace.json");
    trace::write_chrome_trace(path).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    let json = Json::parse(&text).expect("artifact is valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
    let spans = intervals(events);
    let pulls: Vec<_> = spans.iter().filter(|(_, n, ..)| n == "pull-seg").collect();
    let fwds: Vec<_> = spans.iter().filter(|(_, n, ..)| n == "fwd-layer").collect();
    assert!(!pulls.is_empty() && !fwds.is_empty(), "both span kinds exported");
    let overlapping = pulls.iter().any(|(ptid, _, pb, pe)| {
        fwds.iter().any(|(ftid, _, fb, fe)| ptid != ftid && pb < fe && fb < pe)
    });
    assert!(
        overlapping,
        "no pull-seg span overlapped a fwd-layer span on another thread"
    );
    // The server side traced its own half of the run too.
    assert!(spans.iter().any(|(_, n, ..)| n == "assemble"));
    assert!(spans.iter().any(|(_, n, ..)| n == "apply"));
}
