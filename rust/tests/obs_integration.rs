//! End-to-end observability-plane tests (`obs::{registry, trace, expo}`).
//!
//! Same socket-level harness as `sync_integration`: a distributed
//! least-squares problem trained through a real loopback [`ParamServer`]
//! — no PJRT artifacts needed — but here the subject is the telemetry,
//! not the math:
//!
//! * the Prometheus exposition is well-formed line-by-line (property
//!   test over a live scrape);
//! * the Chrome trace export is valid JSON with balanced `B`/`E` events
//!   and per-thread monotone timestamps (golden-shape test);
//! * span rings drop **oldest** at capacity;
//! * steady state allocates nothing even with tracing armed (the pool
//!   allocation counter goes flat while spans keep recording);
//! * the `obs-e2e` scenario CI runs: scrape a training run mid-flight,
//!   assert the key series are present and increasing, and export a
//!   trace (`results/obs_trace.json`) in which pull spans overlap
//!   compute spans on different threads.
//!
//! Obs registrations here go through the `register_*` functions, not the
//! `obs_counter!` macros: dynalint's `metrics` check holds macro sites
//! (production registrations) to the documented catalog, and these are
//! deliberately test-scoped scratch series.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message, MessageRef, TraceCtx, PROTOCOL_VERSION};
use dynacomm::obs;
use dynacomm::obs::expo::{scrape, MetricsServer};
use dynacomm::obs::{clock, critpath, trace};
use dynacomm::ps::sync::SyncConfig;
use dynacomm::ps::worker::record_overlap_drift;
use dynacomm::ps::{AggConfig, ParamServer, RegionalAggregator, ServerConfig, ServerOptions};
use dynacomm::util::json::Json;

/// Both artifact-writing tests export the full-process trace to the same
/// `results/obs_trace.json`, and the harness runs tests in parallel —
/// serialize the writes. (Every export is a full-process snapshot of
/// completed spans, so either ordering leaves valid JSON on disk.)
static ARTIFACT_LOCK: Mutex<()> = Mutex::new(());

const ELEMS: usize = 1500;
const LR: f32 = 0.1;

fn target(j: usize) -> f32 {
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn start_server(workers: usize) -> ParamServer {
    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; ELEMS]);
    ParamServer::start_with(
        ServerConfig { workers, lr: LR },
        layers,
        None,
        ServerOptions::default(),
    )
    .unwrap()
}

fn register(addr: std::net::SocketAddr, worker: u32) -> Connection {
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
    match conn.recv().unwrap() {
        Message::HelloAck { version, .. } => assert_eq!(version, PROTOCOL_VERSION),
        m => panic!("{m:?}"),
    }
    conn
}

/// One pull + push round trip of the least-squares worker.
fn train_step(conn: &mut Connection, iter: u64) {
    conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
    let data = match conn.recv().unwrap() {
        Message::PullReply { data, .. } => data,
        m => panic!("{m:?}"),
    };
    let w = slab::to_f32s(&data);
    let grad: Vec<f32> =
        w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
    conn.send(&Message::Push {
        iter,
        lo: 0,
        hi: 0,
        codec: CodecId::Fp32,
        data: slab::from_f32s(&grad),
    })
    .unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
}

/// The total of a series across instances as read from a scrape body,
/// summing every sample line whose name part is exactly `name`.
fn scraped_total(body: &str, name: &str) -> Option<f64> {
    let mut total = 0.0;
    let mut hit = false;
    for line in body.lines() {
        if line.starts_with('#') {
            continue;
        }
        let series = line.split(['{', ' ']).next().unwrap_or("");
        if series != name {
            continue;
        }
        let value: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        total += value;
        hit = true;
    }
    hit.then_some(total)
}

/// Property: every line of a live scrape is either a `# TYPE name kind`
/// comment with a known kind, or a `name{labels} value` sample whose
/// value parses as a finite f64 and whose label fragment carries the
/// automatic `inst=` tag.
#[test]
fn exposition_format_is_wellformed_line_by_line() {
    let c = obs::register_counter("obstest_expo_events_total", "", obs::next_inst());
    c.add(7);
    let g = obs::register_gauge("obstest_expo_depth", "shard=\"0\"", obs::next_inst());
    g.set(-2.5);
    let h = obs::register_histogram("obstest_expo_lat_ms", "", obs::next_inst());
    for v in [0.02, 1.0, 300.0, 7e6] {
        h.observe(v);
    }

    let mut srv = MetricsServer::bind("127.0.0.1:0").unwrap();
    let body = scrape(srv.addr()).unwrap();
    srv.shutdown();

    assert!(!body.is_empty());
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap();
            let kind = it.next().unwrap();
            assert!(it.next().is_none(), "trailing junk in TYPE line: {line}");
            assert!(!name.is_empty());
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown kind in {line}"
            );
            continue;
        }
        // Sample line: name{labels} value
        let (series, value) = line.rsplit_once(' ').expect(line);
        let v: f64 = value.parse().expect(line);
        assert!(v.is_finite(), "non-finite sample: {line}");
        let (name, labels) = series.split_once('{').expect(line);
        assert!(!name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'));
        let labels = labels.strip_suffix('}').expect(line);
        assert!(
            labels.split(',').any(|kv| kv.starts_with("inst=")),
            "missing automatic inst label: {line}"
        );
        samples += 1;
    }
    assert!(samples >= 3, "scrape carried our series:\n{body}");

    // Our registered values actually round-tripped.
    assert_eq!(scraped_total(&body, "obstest_expo_events_total"), Some(7.0));
    assert_eq!(scraped_total(&body, "obstest_expo_depth"), Some(-2.5));
    assert_eq!(scraped_total(&body, "obstest_expo_lat_ms_count"), Some(4.0));
    assert!(body.contains("obstest_expo_lat_ms_bucket"));
    assert!(body.contains("le=\"+Inf\""));
}

/// Golden-shape test for the Chrome trace export: parses as JSON, every
/// event is `B`/`E`/`M`, `B` and `E` balance per `(tid, name)`, and each
/// thread's timeline is monotone in `ts`.
#[test]
fn chrome_trace_export_is_valid_balanced_and_monotone() {
    trace::set_enabled(true);
    let gate = Arc::new(Barrier::new(2));
    let g2 = gate.clone();
    let t = std::thread::Builder::new()
        .name("obstest-golden".to_string())
        .spawn(move || {
            g2.wait();
            for _ in 0..3 {
                let _outer = trace::span(trace::SPAN_PULL_SEG);
                let _inner = trace::span(trace::SPAN_DECODE_SEG);
                std::thread::sleep(Duration::from_millis(1));
            }
        })
        .unwrap();
    gate.wait();
    for _ in 0..3 {
        let _sp = trace::span(trace::SPAN_FWD_LAYER);
        std::thread::sleep(Duration::from_millis(1));
    }
    t.join().unwrap();

    let text = trace::chrome_trace_json();
    let json = Json::parse(&text).expect("trace is valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());

    let mut balance: HashMap<(u64, String), i64> = HashMap::new();
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
        match ph {
            "M" => continue, // thread_name metadata carries no ts
            // Flow arrows (v7 cross-process links, possibly recorded by a
            // concurrently running test in this process-global export):
            // their ts sits at their endpoints' begins, outside this
            // per-lane monotonicity contract.
            "s" | "f" => {
                assert_eq!(name, "ctx", "flow arrows are named ctx");
                continue;
            }
            "B" | "E" => {
                assert!(
                    trace::SPAN_NAMES.contains(&name.as_str()),
                    "unknown span name {name}"
                );
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
                assert!(
                    ts >= *prev,
                    "tid {tid}: ts went backwards ({ts} after {prev})"
                );
                *prev = ts;
                *balance.entry((tid, name)).or_insert(0) +=
                    if ph == "B" { 1 } else { -1 };
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    for ((tid, name), v) in &balance {
        assert_eq!(*v, 0, "unbalanced B/E for {name} on tid {tid}");
    }
    // Our two threads' spans made it in.
    assert!(balance.keys().any(|(_, n)| n == "pull-seg"));
    assert!(balance.keys().any(|(_, n)| n == "fwd-layer"));
}

#[test]
fn span_ring_drops_oldest_at_capacity() {
    let ring = trace::Ring::new(8);
    for i in 0..20u64 {
        ring.record(trace::SPAN_FWD_LAYER, i, i + 1);
    }
    let got = ring.snapshot();
    assert_eq!(got.len(), 8, "ring holds exactly its capacity");
    let begins: Vec<u64> = got.iter().map(|(_, b, _)| *b).collect();
    assert_eq!(begins, (12..20).collect::<Vec<u64>>(), "newest retained, oldest first");
}

/// The headline zero-alloc claim with the obs plane fully armed: after
/// warm-up, further pull/push iterations allocate nothing — the pool
/// allocation counter stays flat while tracing records spans for every
/// request the whole time.
#[test]
fn steady_state_allocates_nothing_with_tracing_enabled() {
    trace::set_enabled(true);
    let srv = start_server(1);
    let mut conn = register(srv.handle().addr, 0);
    for iter in 0..4 {
        train_step(&mut conn, iter);
    }
    let warm = srv.wire_stats();
    for iter in 4..16 {
        train_step(&mut conn, iter);
    }
    let steady = srv.wire_stats();
    assert_eq!(
        steady.pool.allocations, warm.pool.allocations,
        "steady-state iterations allocated: {:?} -> {:?}",
        warm.pool, steady.pool
    );
    assert!(
        steady.pool.recycled > warm.pool.recycled,
        "pool kept serving checkouts from the free list"
    );
    drop(conn);
    drop(srv); // Drop shuts the server down and joins its handlers.
}

/// Reconstruct `(tid, name, begin_us, end_us)` intervals from a Chrome
/// trace's `B`/`E` stream (per-tid, per-name FIFO pairing — our probe
/// spans never self-nest).
fn intervals(events: &[Json]) -> Vec<(u64, String, f64, f64)> {
    let mut open: HashMap<(u64, String), Vec<f64>> = HashMap::new();
    let mut out = Vec::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        if ph != "B" && ph != "E" {
            continue;
        }
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        if ph == "B" {
            open.entry((tid, name)).or_default().push(ts);
        } else if let Some(begin) = open.get_mut(&(tid, name.clone())).and_then(Vec::pop) {
            out.push((tid, name, begin, ts));
        }
    }
    out
}

/// The CI `obs-e2e` scenario: loopback BSP training with the scrape
/// endpoint live, two mid-run scrapes asserting the key series are
/// present and increasing, a populated overlap-drift histogram, and a
/// trace artifact in which pull spans overlap compute spans.
#[test]
fn obs_e2e_scrape_mid_run_and_trace_artifact() {
    trace::set_enabled(true);
    let srv = start_server(1);
    let mut metrics = MetricsServer::bind("127.0.0.1:0").unwrap();
    let mut conn = register(srv.handle().addr, 0);

    for iter in 0..3 {
        train_step(&mut conn, iter);
    }
    let first = scrape(metrics.addr()).unwrap();
    let pulls_1 = scraped_total(&first, "dynacomm_server_pull_replies_total")
        .expect("pull counter scraped mid-run");
    let applies_1 = scraped_total(&first, "dynacomm_server_apply_events_total")
        .expect("apply counter scraped mid-run");
    assert!(pulls_1 >= 3.0, "served pulls visible: {pulls_1}");
    assert!(applies_1 >= 3.0, "applied pushes visible: {applies_1}");
    assert!(
        scraped_total(&first, "dynacomm_net_rx_frames_total").unwrap_or(0.0) > 0.0,
        "transport counters visible"
    );

    // The overlap audit's sink, fed here exactly as EdgeWorker feeds it.
    record_overlap_drift(true, 12.0, 10.5);
    record_overlap_drift(false, 30.0, 33.0);

    for iter in 3..6 {
        train_step(&mut conn, iter);
    }
    let second = scrape(metrics.addr()).unwrap();
    let pulls_2 =
        scraped_total(&second, "dynacomm_server_pull_replies_total").unwrap();
    let applies_2 =
        scraped_total(&second, "dynacomm_server_apply_events_total").unwrap();
    assert!(pulls_2 > pulls_1, "pulls increased: {pulls_1} -> {pulls_2}");
    assert!(applies_2 > applies_1, "applies increased: {applies_1} -> {applies_2}");
    assert!(
        scraped_total(&second, "dynacomm_overlap_drift_ms_count").unwrap() >= 2.0,
        "drift histogram populated and scraped"
    );

    drop(conn);
    drop(srv);
    metrics.shutdown();

    // Worker-shaped overlap: a puller thread holds pull-seg spans while
    // this thread runs fwd-layer spans through the same wall-clock
    // window — the schedule overlap the paper is about, in trace form.
    let gate = Arc::new(Barrier::new(2));
    let g2 = gate.clone();
    let puller = std::thread::Builder::new()
        .name("obstest-puller".to_string())
        .spawn(move || {
            g2.wait();
            let _sp = trace::span(trace::SPAN_PULL_SEG);
            std::thread::sleep(Duration::from_millis(60));
        })
        .unwrap();
    gate.wait();
    std::thread::sleep(Duration::from_millis(5));
    for _ in 0..4 {
        let _sp = trace::span(trace::SPAN_FWD_LAYER);
        std::thread::sleep(Duration::from_millis(5));
    }
    puller.join().unwrap();

    let _artifact = ARTIFACT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/results/obs_trace.json");
    trace::write_chrome_trace(path).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    let json = Json::parse(&text).expect("artifact is valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
    let spans = intervals(events);
    let pulls: Vec<_> = spans.iter().filter(|(_, n, ..)| n == "pull-seg").collect();
    let fwds: Vec<_> = spans.iter().filter(|(_, n, ..)| n == "fwd-layer").collect();
    assert!(!pulls.is_empty() && !fwds.is_empty(), "both span kinds exported");
    let overlapping = pulls.iter().any(|(ptid, _, pb, pe)| {
        fwds.iter().any(|(ftid, _, fb, fe)| ptid != ftid && pb < fe && fb < pe)
    });
    assert!(
        overlapping,
        "no pull-seg span overlapped a fwd-layer span on another thread"
    );
    // The server side traced its own half of the run too.
    assert!(spans.iter().any(|(_, n, ..)| n == "assemble"));
    assert!(spans.iter().any(|(_, n, ..)| n == "apply"));
}

const FLEET_WORKERS: usize = 2;
const FLEET_ITERS: u64 = 6;
/// Skew injected into the shard's clock (75 ms): large against the 5 ms
/// containment slop below, so the assertions only pass if the probe
/// measured it and the export removed it.
const FLEET_SKEW_NS: i64 = 75_000_000;

/// One completed span from the exported trace, with its fleet links.
struct LSpan {
    pid: u64,
    node: String,
    name: String,
    begin: f64,
    end: f64,
    id: u32,
    parent: u32,
}

/// Pair every `B`/`E` into completed spans (per-lane stack — the export
/// is well nested by construction) and index them by span id.
fn linked_spans(events: &[Json]) -> (Vec<LSpan>, HashMap<u32, usize>) {
    let mut node_of_pid: HashMap<u64, String> = HashMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) == Some("M")
            && e.get("name").and_then(Json::as_str) == Some("process_name")
        {
            let pid = e.get("pid").and_then(Json::as_f64).unwrap() as u64;
            let name =
                e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str).unwrap();
            node_of_pid.insert(pid, name.to_string());
        }
    }
    let mut stacks: HashMap<(u64, u64), Vec<LSpan>> = HashMap::new();
    let mut spans = Vec::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        if ph != "B" && ph != "E" {
            continue;
        }
        let pid = e.get("pid").and_then(Json::as_f64).unwrap() as u64;
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        if ph == "B" {
            let arg = |k: &str| {
                e.get("args").and_then(|a| a.get(k)).and_then(Json::as_f64).unwrap_or(0.0)
                    as u32
            };
            stacks.entry((pid, tid)).or_default().push(LSpan {
                pid,
                node: node_of_pid.get(&pid).cloned().unwrap_or_default(),
                name: e.get("name").and_then(Json::as_str).unwrap().to_string(),
                begin: ts,
                end: ts,
                id: arg("id"),
                parent: arg("parent"),
            });
        } else {
            let mut s = stacks
                .get_mut(&(pid, tid))
                .and_then(Vec::pop)
                .expect("balanced B/E per lane");
            s.end = ts;
            spans.push(s);
        }
    }
    let by_id = spans
        .iter()
        .enumerate()
        .filter(|(_, s)| s.id != 0)
        .map(|(i, s)| (s.id, i))
        .collect();
    (spans, by_id)
}

/// One traced fleet train step against the aggregator: pull (flow-linked
/// to the reply's fan-out context), a deliberate compute span, push
/// carrying this worker's v7 trace context.
fn fleet_step(conn: &mut Connection, iter: u64) {
    let data = {
        let mut sp = trace::span(trace::SPAN_PULL_SEG);
        conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
        let (msg, ctx) = conn.recv_ref_ctx().unwrap();
        let data = match msg {
            MessageRef::PullReply { data, .. } => data.to_vec(),
            m => panic!("{:?}", m.into_owned()),
        };
        if let Some(c) = ctx.filter(TraceCtx::is_reply) {
            sp.set_flow_from(c.parent_span);
        }
        data
    };
    let grad: Vec<f32> = {
        let _fwd = trace::span(trace::SPAN_FWD_LAYER);
        // Deliberate compute floor: keeps each iteration's wall time well
        // above scheduling noise so the 10% breakdown check is stable.
        std::thread::sleep(Duration::from_millis(8));
        slab::to_f32s(&data)
            .iter()
            .enumerate()
            .map(|(j, v)| 2.0 * (v - target(j)))
            .collect()
    };
    let mut sp = trace::span(trace::SPAN_PUSH_SEG);
    let ctx = (sp.id() != 0)
        .then(|| TraceCtx::sampled(trace::trace_id_for(iter), sp.id()));
    conn.send_ctx(
        &Message::Push {
            iter,
            lo: 0,
            hi: 0,
            codec: CodecId::Fp32,
            data: slab::from_f32s(&grad),
        },
        ctx,
    )
    .unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    drop(sp);
}

/// The fleet-tracing acceptance scenario: 2 workers x 1 aggregator x 1
/// shard over loopback, shard clock skewed by 75 ms. Asserts the three
/// v7 contracts end to end:
///
/// * every iteration's combined push reaches the shard as an `apply`
///   span whose parent chain (apply -> agg-forward -> worker push-seg)
///   crosses process lanes, with a flow arrow (`s`/`f`) stitching it;
/// * offset-corrected timestamps keep every parent-linked child span
///   inside its parent's window despite the injected skew;
/// * the critical-path breakdown of every iteration sums to its wall
///   time, and the wall time matches the externally measured iteration
///   time within 10%.
#[test]
fn fleet_trace_e2e_flow_links_skew_correction_and_critical_path() {
    trace::set_enabled(true);
    trace::set_run_seed(0xF1EE7);
    let shard = {
        let mut layers = HashMap::new();
        layers.insert(0, vec![0.0f32; ELEMS]);
        ParamServer::start(
            ServerConfig { workers: FLEET_WORKERS, lr: LR },
            layers,
            None,
        )
        .unwrap()
    };
    let shard_node = format!("shard-{}", shard.handle().addr.port());
    // Inject the skew BEFORE the aggregator boots: its upstream connect
    // probes the shard at session establish, and the shard's handler
    // threads adopt the (now skewed) node when the sessions arrive.
    trace::set_node_skew_ns(&shard_node, FLEET_SKEW_NS);
    let mut agg = RegionalAggregator::start(AggConfig {
        group: 200,
        workers: FLEET_WORKERS as u32,
        upstream_addrs: vec![shard.handle().addr],
        layer_elems: vec![ELEMS],
        downstream_sync: SyncConfig::default(),
        upstream_sync: SyncConfig::default(),
        upstream_codec: CodecId::Fp32,
        handler_threads: FLEET_WORKERS + 2,
        io_timeout_ms: 0,
    })
    .unwrap();
    let off = clock::node_offset_ns(&shard_node);
    assert!(
        (off - FLEET_SKEW_NS).abs() < 10_000_000,
        "boot-time probe measured the injected skew: got {off} ns"
    );

    let gate = Arc::new(Barrier::new(FLEET_WORKERS));
    let handles: Vec<_> = (0..FLEET_WORKERS)
        .map(|w| {
            let addr = agg.addr();
            let gate = gate.clone();
            std::thread::Builder::new()
                .name(format!("fleet-worker-{w}"))
                .spawn(move || {
                    trace::adopt_node(&format!("worker-{w}"));
                    let mut conn = register(addr, w as u32);
                    clock::probe_and_note(&mut conn, "agg-200", 3).unwrap();
                    let mut measured_us = Vec::new();
                    for iter in 0..FLEET_ITERS {
                        gate.wait();
                        let t0 = std::time::Instant::now();
                        {
                            let _it = trace::span(trace::SPAN_ITERATION);
                            fleet_step(&mut conn, iter);
                        }
                        measured_us.push(t0.elapsed().as_secs_f64() * 1e6);
                    }
                    measured_us
                })
                .unwrap()
        })
        .collect();
    let measured: Vec<Vec<f64>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Same-process peers: the worker->aggregator offset is genuinely ~0,
    // so the per-peer gauges tell the skewed shard apart from the agg.
    assert!(
        clock::node_offset_ns("agg-200").abs() < 10_000_000,
        "unskewed peer's measured offset stays near zero"
    );
    agg.shutdown();
    drop(shard);

    let _artifact = ARTIFACT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/results/obs_trace.json");
    trace::write_chrome_trace(path).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    let json = Json::parse(&text).expect("fleet trace is valid JSON");
    let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
    let (spans, by_id) = linked_spans(events);

    // (1) Cross-process causality: each iteration's apply span on the
    // shard lane walks apply -> agg-forward -> worker push-seg through
    // its parent links, across distinct process lanes.
    let chained_applies: Vec<&LSpan> = spans
        .iter()
        .filter(|s| s.name == "apply" && s.node == shard_node)
        .filter(|s| {
            let mut cur: &LSpan = s;
            for _ in 0..8 {
                let Some(&j) = by_id.get(&cur.parent) else { return false };
                cur = &spans[j];
                if cur.name == "push-seg" && cur.node.starts_with("worker-") {
                    return cur.pid != s.pid;
                }
            }
            false
        })
        .collect();
    assert!(
        chained_applies.len() >= FLEET_ITERS as usize,
        "every iteration's apply chains back to a worker push across lanes: \
         {} of {FLEET_ITERS}",
        chained_applies.len()
    );
    // ...and each such link is rendered as a flow arrow: the `s` at the
    // parent's begin and the bound `f` at the apply's begin share the
    // apply's parent-kind arrow id.
    let arrow_ids = |ph: &str| -> Vec<u64> {
        events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some(ph)
                    && e.get("name").and_then(Json::as_str) == Some("ctx")
            })
            .map(|e| e.get("id").and_then(Json::as_f64).unwrap() as u64)
            .collect()
    };
    let (starts, finishes) = (arrow_ids("s"), arrow_ids("f"));
    for a in &chained_applies {
        let arrow = (a.id as u64) << 1;
        assert!(starts.contains(&arrow), "flow start for apply span {}", a.id);
        assert!(finishes.contains(&arrow), "flow finish for apply span {}", a.id);
    }

    // (2) Skew correction: every parent-linked child sits inside its
    // parent's window after offset correction. 5 ms of slop for probe
    // error — 15x smaller than the injected 75 ms skew.
    const SLOP_US: f64 = 5_000.0;
    let mut checked = 0usize;
    for s in &spans {
        let Some(&j) = by_id.get(&s.parent) else { continue };
        let p = &spans[j];
        assert!(
            s.begin >= p.begin - SLOP_US && s.end <= p.end + SLOP_US,
            "{} [{:.0}, {:.0}]us escapes its parent {} [{:.0}, {:.0}]us",
            s.name,
            s.begin,
            s.end,
            p.name,
            p.begin,
            p.end
        );
        checked += 1;
    }
    assert!(
        checked >= 3 * FLEET_ITERS as usize,
        "fan-in/forward/apply links all containment-checked: {checked}"
    );

    // (3) Critical path: exact per-iteration accounting, and the span
    // windows agree with the externally measured wall times.
    let report = critpath::analyze(&text).expect("critical-path analysis");
    let report_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/results/obs_trace.json.critpath.json"
    );
    std::fs::write(report_path, report.to_json()).unwrap();
    assert_eq!(
        report.iterations.len(),
        FLEET_WORKERS * FLEET_ITERS as usize,
        "one breakdown per worker iteration"
    );
    for it in &report.iterations {
        let sum: f64 = it.hops_us.iter().sum();
        assert!(
            (sum - it.wall_us).abs() < 1.0,
            "breakdown sums to wall time: {sum} vs {}",
            it.wall_us
        );
    }
    for (w, worker_measured) in measured.iter().enumerate() {
        let node = format!("worker-{w}");
        let rep: Vec<_> =
            report.iterations.iter().filter(|it| it.node == node).collect();
        assert_eq!(rep.len(), FLEET_ITERS as usize, "{node} iterations reported");
        // Report iterations are begin-sorted, so they pair with the
        // worker's own measurements in order.
        for (it, &m_us) in rep.iter().zip(worker_measured) {
            assert!(
                (it.wall_us - m_us).abs() <= 0.10 * m_us,
                "{node}: traced wall {:.0}us vs measured {m_us:.0}us",
                it.wall_us
            );
        }
    }
}
