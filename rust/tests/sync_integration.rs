//! End-to-end synchronization-subsystem tests over the real PS wire path.
//!
//! Mirrors `codec_train`'s harness: the "model" is a distributed
//! least-squares problem (`min_w ‖w − target‖²`) trained through a real
//! loopback [`ParamServer`] — no PJRT artifacts needed — but the workers
//! here register (`Hello` + `SyncPropose`) and run under each
//! synchronization mode (`ps::sync`):
//!
//! * **bsp** — byte-identical loss curves across workers (the barrier);
//! * **ssp** — per-worker strictly decreasing loss, every reply within
//!   the staleness bound (checked from the v4 `applied` field), plus a
//!   driver-controlled interleaving property test: *no worker ever
//!   observes a snapshot older than `slowest − N`*;
//! * **asp** — per-worker strictly decreasing loss with no gating at all.
//!
//! The CI sync matrix runs `sync_training_converges_selected_mode` once
//! per mode via `DYNACOMM_SYNC`; the per-mode tests below keep all three
//! exercised in every plain `cargo test` run too. The file also hosts the
//! EF-SGD convergence comparison (int8 + error feedback must end no worse
//! than plain int8 on the same model — `net::codec::ef`).

use std::collections::HashMap;
use std::net::TcpStream;

use dynacomm::net::codec::ef::ErrorFeedback;
use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message, PROTOCOL_VERSION};
use dynacomm::ps::sync::{SyncConfig, SyncMode};
use dynacomm::ps::{ParamServer, ServerConfig, ServerOptions};
use dynacomm::util::rng::Rng;

/// Crosses an int8 chunk boundary (CHUNK = 1024), like `codec_train`.
const ELEMS: usize = 1500;
const WORKERS: usize = 2;
/// Enough iterations that even a worker whose peer finished first (ASP:
/// only its own applies remain) still lands far below its starting loss.
const ITERS: u64 = 12;
const LR: f32 = 0.1;

fn target(j: usize) -> f32 {
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn loss_of(w: &[f32]) -> f32 {
    w.iter().enumerate().map(|(j, v)| (v - target(j)).powi(2)).sum::<f32>()
        / w.len() as f32
}

fn start_server(mode: SyncMode, bound: u32, workers: usize) -> ParamServer {
    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; ELEMS]);
    ParamServer::start_with(
        ServerConfig { workers, lr: LR },
        layers,
        None,
        ServerOptions {
            sync: SyncConfig::new(mode, bound).unwrap(),
            ..ServerOptions::default()
        },
    )
    .unwrap()
}

/// Register a worker session: version handshake + sync agreement.
fn register(addr: std::net::SocketAddr, worker: u32, mode: SyncMode, bound: u32) -> Connection {
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    conn.send(&Message::Hello { worker, version: PROTOCOL_VERSION }).unwrap();
    match conn.recv().unwrap() {
        Message::HelloAck { version, .. } => assert_eq!(version, PROTOCOL_VERSION),
        m => panic!("{m:?}"),
    }
    conn.send(&Message::SyncPropose { mode, bound }).unwrap();
    match conn.recv().unwrap() {
        Message::SyncAgree { mode: got, bound: got_bound } => {
            assert_eq!(got, mode, "server must run the proposed mode in these tests");
            assert_eq!(got_bound, bound);
        }
        m => panic!("{m:?}"),
    }
    conn
}

/// One iteration of the least-squares worker on an open session: pull,
/// measure loss, push the exact gradient. Returns (applied, loss).
fn train_step(conn: &mut Connection, iter: u64) -> (u64, f32) {
    conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
    let (applied, data) = match conn.recv().unwrap() {
        Message::PullReply { applied, data, .. } => (applied, data),
        m => panic!("{m:?}"),
    };
    let w = slab::to_f32s(&data);
    let loss = loss_of(&w);
    let grad: Vec<f32> =
        w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
    conn.send(&Message::Push {
        iter,
        lo: 0,
        hi: 0,
        codec: CodecId::Fp32,
        data: slab::from_f32s(&grad),
    })
    .unwrap();
    assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    (applied, loss)
}

/// Train `WORKERS` concurrent registered workers under `mode`; returns
/// each worker's loss curve after asserting the mode's staleness
/// contract on every reply.
fn train_under(mode: SyncMode, bound: u32) -> Vec<Vec<f32>> {
    let srv = start_server(mode, bound, WORKERS);
    let addr = srv.handle().addr;
    let threads: Vec<_> = (0..WORKERS as u32)
        .map(|w| {
            std::thread::spawn(move || {
                let mut conn = register(addr, w, mode, bound);
                let mut losses = Vec::with_capacity(ITERS as usize);
                for iter in 0..ITERS {
                    let (applied, loss) = train_step(&mut conn, iter);
                    match mode {
                        SyncMode::Bsp => assert_eq!(applied, iter),
                        SyncMode::Ssp => assert!(
                            iter.saturating_sub(applied) <= bound as u64,
                            "worker {w}: iter {iter} served applied {applied} \
                             past bound {bound}"
                        ),
                        SyncMode::Asp => {}
                    }
                    losses.push(loss);
                }
                losses
            })
        })
        .collect();
    threads.into_iter().map(|t| t.join().unwrap()).collect()
}

/// Every mode's acceptance property: per-worker loss strictly decreases
/// and ends far below where it started.
fn assert_converges(mode: SyncMode, bound: u32) {
    let curves = train_under(mode, bound);
    for (w, losses) in curves.iter().enumerate() {
        assert_eq!(losses.len(), ITERS as usize);
        for k in 1..losses.len() {
            assert!(
                losses[k] < losses[k - 1],
                "{}: worker {w} loss did not strictly decrease at iter {k}: {losses:?}",
                mode.name()
            );
        }
        assert!(
            losses[losses.len() - 1] < 0.2 * losses[0],
            "{}: worker {w} not enough progress: {losses:?}",
            mode.name()
        );
    }
    if mode == SyncMode::Bsp {
        // The barrier makes every worker see byte-identical parameters.
        for c in &curves[1..] {
            assert_eq!(c, &curves[0], "workers diverged under BSP");
        }
    }
}

#[test]
fn sync_training_converges_bsp() {
    assert_converges(SyncMode::Bsp, 0);
}

#[test]
fn sync_training_converges_ssp() {
    assert_converges(SyncMode::Ssp, 2);
}

#[test]
fn sync_training_converges_asp() {
    assert_converges(SyncMode::Asp, 0);
}

/// CI matrix entry point: `DYNACOMM_SYNC={bsp,ssp,asp}` picks the mode
/// (default ssp), so every PR trains end-to-end under each consistency
/// model.
#[test]
fn sync_training_converges_selected_mode() {
    let mode = std::env::var("DYNACOMM_SYNC")
        .ok()
        .and_then(|s| SyncMode::parse(&s))
        .unwrap_or(SyncMode::Ssp);
    let bound = if mode == SyncMode::Ssp { 2 } else { 0 };
    assert_converges(mode, bound);
}

/// The SSP consistency property, driven single-threaded so every
/// interleaving step is controlled: across a random schedule of worker
/// advances (each within its admission window, so nothing parks), **no
/// pull is ever served a snapshot older than `slowest − N`** — in fact
/// never older than `slowest` itself — and never past the worker's own
/// clock minus the bound.
#[test]
fn ssp_property_no_snapshot_older_than_slowest_minus_bound() {
    const BOUND: u32 = 2;
    let srv = start_server(SyncMode::Ssp, BOUND, WORKERS);
    let addr = srv.handle().addr;
    let mut conns: Vec<Connection> = (0..WORKERS as u32)
        .map(|w| register(addr, w, SyncMode::Ssp, BOUND))
        .collect();
    // The driver's own model of each worker's clock (next iteration).
    let mut clock = vec![0u64; WORKERS];
    let mut rng = Rng::new(515);
    for _ in 0..60 {
        // Pick a worker whose next pull is admissible (≤ slowest + N once
        // its own clock advances), so the single-threaded driver never
        // parks: the slowest worker always qualifies.
        let candidates: Vec<usize> = (0..WORKERS)
            .filter(|&w| {
                let slowest_rest =
                    clock.iter().enumerate().filter(|&(o, _)| o != w).map(|(_, &c)| c)
                        .min()
                        .unwrap_or(clock[w]);
                clock[w] <= slowest_rest + BOUND as u64
            })
            .collect();
        assert!(!candidates.is_empty(), "the slowest worker always qualifies");
        let w = candidates[rng.below(candidates.len())];
        let iter = clock[w];
        let slowest_before = *clock.iter().min().unwrap();
        let (applied, _) = train_step(&mut conns[w], iter);
        clock[w] = iter + 1;
        // The property under test (two forms: vs the fleet's slowest and
        // vs the puller's own clock).
        assert!(
            applied + (BOUND as u64) >= slowest_before,
            "snapshot {applied} older than slowest {slowest_before} − {BOUND}"
        );
        assert!(
            applied + (BOUND as u64) >= iter,
            "worker {w} at iter {iter} observed applied {applied} past the bound"
        );
        // And the stronger invariant this server actually provides: the
        // snapshot is never older than the slowest worker's clock (every
        // worker has pushed everything below its own clock).
        assert!(
            applied >= slowest_before,
            "applied {applied} vs slowest {slowest_before}"
        );
    }
}

// ---- EF-SGD (error feedback) convergence comparison ----

/// Train the least-squares model over a single registered BSP worker,
/// pulling exact fp32 parameters and pushing **int8-quantized gradients**
/// (every `Push` frame is decoded by its own codec tag, so the gradient
/// wire path is the only quantized leg — exactly what EF compensates),
/// optionally carrying EF residuals. Returns the final **server-side**
/// loss from the full-precision snapshot.
fn train_int8(ef: bool, iters: u64) -> f32 {
    let srv = start_server(SyncMode::Bsp, 0, 1);
    let addr = srv.handle().addr;
    let mut conn = register(addr, 0, SyncMode::Bsp, 0);
    let wc = CodecId::Int8.codec();
    let mut feedback = ErrorFeedback::new(&[ELEMS]);
    for iter in 0..iters {
        conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
        let data = match conn.recv().unwrap() {
            Message::PullReply { data, .. } => data,
            m => panic!("{m:?}"),
        };
        let w = slab::to_f32s(&data);
        let grad: Vec<f32> =
            w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
        let mut raw_grad = slab::from_f32s(&grad);
        let mut wire = Vec::new();
        if ef {
            feedback.encode(0, wc, &mut raw_grad, &mut wire).unwrap();
        } else {
            wc.encode(&raw_grad, &mut wire);
        }
        conn.send(&Message::Push {
            iter,
            lo: 0,
            hi: 0,
            codec: CodecId::Int8,
            data: wire,
        })
        .unwrap();
        assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    }
    loss_of(&srv.snapshot(0).unwrap())
}

/// The EF-SGD acceptance property: int8 + error feedback trains the
/// least-squares model to a loss no worse than plain int8. On this convex
/// model the affine quantizer's error contracts with the gradient, so the
/// two runs converge to near-identical floors (EF's decisive win — the
/// bias of repeated rounding averaged away — is pinned down
/// deterministically in `net::codec::ef`'s unit tests); both runs are
/// deterministic and the small relative slack only covers f32
/// accumulation order.
#[test]
fn int8_with_error_feedback_is_no_worse() {
    let iters = 24;
    let plain = train_int8(false, iters);
    let with_ef = train_int8(true, iters);
    let initial = loss_of(&vec![0.0f32; ELEMS]);
    assert!(
        with_ef <= plain * 1.01 + 1e-12,
        "EF ended worse: ef {with_ef:e} vs plain {plain:e}"
    );
    assert!(with_ef < 1e-3 * initial, "EF run did not converge: {with_ef:e}");
    assert!(plain < 1e-3 * initial, "plain run did not converge: {plain:e}");
}
