//! End-to-end PS framework integration over real loopback TCP with the
//! real PJRT runtime — requires `make artifacts` (no-ops otherwise).
//!
//! The headline test is the paper's Fig. 10 claim reduced to its essence:
//! layer-wise communication scheduling must not change the computed math,
//! so the loss sequence under DynaComm is *identical* to Sequential.

use dynacomm::config::Strategy;
use dynacomm::runtime::artifacts_available;
use dynacomm::training::{train, TrainConfig};

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn base_cfg() -> Option<TrainConfig> {
    if !artifacts_available(DIR) {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(TrainConfig {
        artifacts_dir: DIR.to_string(),
        workers: 1,
        servers: 2,
        epochs: 1,
        iters_per_epoch: 3,
        // Fast emulated link: these tests check correctness, not timing.
        setup_ms: 0.1,
        latency_ms: 0.05,
        bytes_per_ms: 10_000_000.0,
        val_batches: 1,
        ..TrainConfig::default()
    })
}

#[test]
fn training_runs_and_learns_signal() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.epochs = 2;
    cfg.iters_per_epoch = 5;
    let r = train(&cfg).unwrap();
    assert_eq!(r.epoch_loss.len(), 2);
    assert!(r.epoch_loss.iter().all(|l| l.is_finite()));
    // Loss must drop from the first to the last epoch on this easy task.
    assert!(
        r.epoch_loss[1] < r.epoch_loss[0],
        "loss did not improve: {:?}",
        r.epoch_loss
    );
    assert!(r.samples_per_sec_per_worker > 0.0);
    assert_eq!(r.final_params.len(), 6);
}

/// Scheduling strategies change *when* tensors move, never *what* is
/// computed: with a single worker (deterministic update order) every
/// strategy must produce bit-identical loss sequences.
#[test]
fn fig10_property_loss_identical_across_strategies() {
    let Some(cfg) = base_cfg() else { return };
    let mut sequences = Vec::new();
    for strategy in [Strategy::Sequential, Strategy::LayerByLayer, Strategy::DynaComm] {
        let mut c = cfg.clone();
        c.strategy = strategy;
        c.epochs = 2; // cross a reschedule boundary
        c.iters_per_epoch = 3;
        let r = train(&c).unwrap();
        sequences.push((strategy, r.per_worker[0].losses.clone()));
    }
    let (_, ref baseline) = sequences[0];
    for (s, seq) in &sequences[1..] {
        assert_eq!(
            seq, baseline,
            "{} diverged from sequential: {seq:?} vs {baseline:?}",
            s.name()
        );
    }
}

#[test]
fn multi_worker_bsp_converges() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.workers = 2;
    cfg.servers = 2;
    cfg.epochs = 2;
    cfg.iters_per_epoch = 4;
    let r = train(&cfg).unwrap();
    assert_eq!(r.per_worker.len(), 2);
    // BSP: both workers ran the same number of iterations.
    assert_eq!(r.per_worker[0].losses.len(), r.per_worker[1].losses.len());
    assert!(r.epoch_loss.iter().all(|l| l.is_finite()));
}

/// Run-to-run determinism with one worker: the whole pipeline (dataset,
/// init, BSP updates) is reproducible.
#[test]
fn single_worker_training_is_deterministic() {
    let Some(cfg) = base_cfg() else { return };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.per_worker[0].losses, b.per_worker[0].losses);
    for ((wa, ba), (wb, bb)) in a.final_params.iter().zip(&b.final_params) {
        assert_eq!(wa.data, wb.data);
        assert_eq!(ba.data, bb.data);
    }
}

/// Gain-thresholded re-planning end to end: with a huge threshold the DP
/// re-plan is skipped (and counted in `WorkerReport::sched_reused`) after
/// the first profiled re-plan; with the default threshold 0 every re-plan
/// call runs the DP.
#[test]
fn gain_threshold_skips_and_counts_replans() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.strategy = Strategy::DynaComm;
    cfg.epochs = 4; // reschedule boundaries at iters 3, 6, 9
    cfg.iters_per_epoch = 3;
    cfg.gain_threshold_ms = f64::INFINITY;
    let r = train(&cfg).unwrap();
    let rep = &r.per_worker[0];
    assert!(rep.sched_ms.len() >= 2, "expected multiple re-plan calls");
    // The first profiled call computes the DP and records the plan change
    // (away from the LBL bootstrap); every later call must be answered
    // from the cache and counted.
    assert_eq!(rep.plans.len(), 1, "{:?}", rep.plans);
    assert_eq!(rep.sched_reused, rep.sched_ms.len() - 1);
    assert!(rep.sched_reused >= 1, "cached plan never reused");
    // Every call (fresh or reused) records the scheduler's own prediction.
    assert_eq!(rep.sched_predicted_ms.len(), rep.sched_ms.len());
    assert!(rep.sched_predicted_ms.iter().all(|p| p.is_finite() && *p > 0.0));

    // Default threshold 0: the DP runs on every call, nothing is reused —
    // though a stable profile may keep reproducing the same plan, so only
    // the first change is guaranteed to be recorded.
    cfg.gain_threshold_ms = 0.0;
    let r = train(&cfg).unwrap();
    let rep = &r.per_worker[0];
    assert_eq!(rep.sched_reused, 0);
    assert!(!rep.plans.is_empty());
    assert!(rep.plans.len() <= rep.sched_ms.len());
}

/// The profiler must accumulate usable cost vectors from a real run and
/// produce a DynaComm plan that differs from naive LBL when Δt is large.
#[test]
fn profiler_feeds_scheduler_with_real_measurements() {
    let Some(mut cfg) = base_cfg() else { return };
    // Make Δt dominant so batching is clearly optimal.
    cfg.setup_ms = 20.0;
    cfg.bytes_per_ms = 50_000_000.0;
    cfg.strategy = Strategy::DynaComm;
    cfg.epochs = 2; // epoch boundary triggers a reschedule from profile
    cfg.iters_per_epoch = 3;
    let r = train(&cfg).unwrap();
    let rep = &r.per_worker[0];
    assert!(!rep.plans.is_empty(), "no reschedule happened");
    let last = rep.plans[rep.plans.len() - 1];
    // With 20 ms setup per mini-procedure and ~1 MB of parameters, the DP
    // must consolidate well below one-transmission-per-layer.
    assert!(last.fwd_segments < 6, "fwd segments = {}", last.fwd_segments);
    assert!(last.bwd_segments <= 6, "bwd segments = {}", last.bwd_segments);
    assert!(last.sched_ms >= 0.0);
    assert!(!rep.sched_ms.is_empty());
}
