//! End-to-end **quantized training** over the real PS wire path.
//!
//! These tests need no PJRT artifacts: the "model" is a distributed
//! least-squares problem (`min_w ‖w − target‖²`) trained through a real
//! loopback [`ParamServer`] by BSP workers that pull codec-encoded
//! parameters, compute exact gradients in plain Rust, and push
//! codec-encoded gradients. That exercises the whole v3 codec surface —
//! negotiation, encode-reply, decode-accumulate, per-codec reply caching —
//! under actual SGD, and the acceptance property is the one that matters
//! for training: **the loss strictly decreases** despite quantization.
//!
//! The CI codec matrix runs `quantized_training_converges_selected_codec`
//! once per codec via `DYNACOMM_CODEC`; the per-codec tests below keep all
//! three exercised in every plain `cargo test` run too.
//!
//! A final artifact-gated test trains the real EdgeCNN through PJRT with
//! `--codec int8` when `make artifacts` has been run (it no-ops
//! otherwise, like `ps_integration`).

use std::collections::HashMap;
use std::net::TcpStream;

use dynacomm::net::codec::CodecId;
use dynacomm::net::{slab, Connection, Message};
use dynacomm::ps::{ParamServer, ServerConfig};

/// Elements in the parameter vector: crosses an int8 chunk boundary
/// (CHUNK = 1024), so multi-chunk framing is part of the run.
const ELEMS: usize = 1500;
const WORKERS: usize = 2;
const ITERS: u64 = 8;
const LR: f32 = 0.1;

fn target(j: usize) -> f32 {
    // Spread in [-1, 1] so quantization ranges are non-degenerate.
    ((j as f32 * 0.7153).sin() * 997.0).fract().clamp(-1.0, 1.0)
}

fn negotiate(conn: &mut Connection, pref: CodecId) -> CodecId {
    conn.send(&Message::CodecPropose { pref }).unwrap();
    match conn.recv().unwrap() {
        Message::CodecAgree { codec } => codec,
        m => panic!("bad codec agreement: {m:?}"),
    }
}

/// One BSP worker: pull → decode → grad = 2(w − target) → encode → push.
/// Returns the per-iteration loss sequence measured from the decoded
/// parameters (i.e. what a real training loop would see).
fn run_worker(addr: std::net::SocketAddr, codec: CodecId) -> Vec<f32> {
    let wc = codec.codec();
    let mut conn = Connection::new(TcpStream::connect(addr).unwrap(), None);
    if codec != CodecId::Fp32 {
        assert_eq!(negotiate(&mut conn, codec), codec, "server must support {codec:?}");
    }
    let mut losses = Vec::with_capacity(ITERS as usize);
    for iter in 0..ITERS {
        conn.send(&Message::Pull { iter, lo: 0, hi: 0 }).unwrap();
        let (rcodec, data) = match conn.recv().unwrap() {
            Message::PullReply { codec, data, .. } => (codec, data),
            m => panic!("{m:?}"),
        };
        assert_eq!(rcodec, codec);
        assert_eq!(data.len(), wc.wire_len(4 * ELEMS), "wire size table broke");
        let mut raw = Vec::new();
        wc.decode(&data, &mut raw).unwrap();
        let w = slab::to_f32s(&raw);
        let loss = w
            .iter()
            .enumerate()
            .map(|(j, v)| (v - target(j)).powi(2))
            .sum::<f32>()
            / ELEMS as f32;
        losses.push(loss);
        let grad: Vec<f32> =
            w.iter().enumerate().map(|(j, v)| 2.0 * (v - target(j))).collect();
        let mut wire = Vec::new();
        wc.encode(&slab::from_f32s(&grad), &mut wire);
        conn.send(&Message::Push { iter, lo: 0, hi: 0, codec, data: wire }).unwrap();
        assert!(matches!(conn.recv().unwrap(), Message::PushAck { .. }));
    }
    losses
}

/// Train the least-squares model over real TCP with `codec` on the wire;
/// returns worker 0's loss curve after asserting BSP agreement.
fn train_quantized(codec: CodecId) -> Vec<f32> {
    let mut layers = HashMap::new();
    layers.insert(0, vec![0.0f32; ELEMS]);
    let srv =
        ParamServer::start(ServerConfig { workers: WORKERS, lr: LR }, layers, None).unwrap();
    let addr = srv.handle().addr;
    let threads: Vec<_> = (0..WORKERS)
        .map(|_| std::thread::spawn(move || run_worker(addr, codec)))
        .collect();
    let curves: Vec<Vec<f32>> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    // BSP: every worker sees byte-identical parameters, so identical loss.
    for c in &curves[1..] {
        assert_eq!(c, &curves[0], "workers diverged under BSP");
    }
    // The codec counters moved on the server for non-fp32 sessions.
    let ws = srv.wire_stats();
    let cs = ws.codec(codec);
    assert!(cs.encodes >= ITERS, "replies not codec-encoded: {cs:?}");
    assert!(cs.decodes >= ITERS, "pushes not codec-decoded: {cs:?}");
    if codec != CodecId::Fp32 {
        assert!(cs.bytes_saved() > 0, "{codec:?} saved no bytes: {cs:?}");
    }
    curves.into_iter().next().unwrap()
}

/// The acceptance property, per codec: loss strictly decreases over the
/// smoke iterations and ends far below where it started.
fn assert_converges(codec: CodecId) {
    let losses = train_quantized(codec);
    assert_eq!(losses.len(), ITERS as usize);
    for k in 1..losses.len() {
        assert!(
            losses[k] < losses[k - 1],
            "{codec:?}: loss did not strictly decrease at iter {k}: {losses:?}"
        );
    }
    assert!(
        losses[losses.len() - 1] < 0.2 * losses[0],
        "{codec:?}: not enough progress: {losses:?}"
    );
}

#[test]
fn quantized_training_converges_fp32() {
    assert_converges(CodecId::Fp32);
}

#[test]
fn quantized_training_converges_fp16() {
    assert_converges(CodecId::Fp16);
}

#[test]
fn quantized_training_converges_int8() {
    assert_converges(CodecId::Int8);
}

/// CI matrix entry point: `DYNACOMM_CODEC={fp32,fp16,int8}` picks the
/// codec (default int8), so every PR trains end-to-end through each codec.
#[test]
fn quantized_training_converges_selected_codec() {
    let codec = std::env::var("DYNACOMM_CODEC")
        .ok()
        .and_then(|s| CodecId::parse(&s))
        .unwrap_or(CodecId::Int8);
    assert_converges(codec);
}

/// Wire-level negotiation property against a live server: every
/// preference converges on a codec the server supports (here: itself),
/// and the session actually speaks it.
#[test]
fn negotiation_converges_on_the_wire() {
    let mut layers = HashMap::new();
    layers.insert(0, vec![1.0f32; 8]);
    let srv = ParamServer::start(ServerConfig { workers: 1, lr: 0.1 }, layers, None).unwrap();
    for pref in CodecId::ALL {
        let mut conn =
            Connection::new(TcpStream::connect(srv.handle().addr).unwrap(), None);
        let agreed = negotiate(&mut conn, pref);
        assert_eq!(agreed, pref);
        conn.send(&Message::Pull { iter: 0, lo: 0, hi: 0 }).unwrap();
        match conn.recv().unwrap() {
            Message::PullReply { codec, .. } => assert_eq!(codec, agreed),
            m => panic!("{m:?}"),
        }
    }
}

/// Real EdgeCNN training through the PJRT artifacts with int8 transfers —
/// the full stack, gated on `make artifacts` like `ps_integration`.
#[test]
fn edgecnn_int8_training_improves() {
    const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !dynacomm::runtime::artifacts_available(DIR) {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = dynacomm::training::TrainConfig {
        artifacts_dir: DIR.to_string(),
        workers: 1,
        servers: 2,
        epochs: 2,
        iters_per_epoch: 5,
        setup_ms: 0.1,
        latency_ms: 0.05,
        bytes_per_ms: 10_000_000.0,
        val_batches: 1,
        codec: CodecId::Int8,
        ..dynacomm::training::TrainConfig::default()
    };
    let r = dynacomm::training::train(&cfg).unwrap();
    assert!(r.epoch_loss.iter().all(|l| l.is_finite()));
    assert!(
        r.epoch_loss[1] < r.epoch_loss[0],
        "int8 training did not improve: {:?}",
        r.epoch_loss
    );
}
