//! Randomized robustness tests for the hand-rolled substrates: the JSON
//! parser and the wire protocol must never panic on arbitrary bytes and
//! must round-trip everything they produce.

use dynacomm::net::codec::CodecId;
use dynacomm::net::{Message, PeerRole, PROTOCOL_VERSION};
use dynacomm::ps::sync::SyncMode;
use dynacomm::util::json::Json;
use dynacomm::util::rng::Rng;

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool()),
        2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
        3 => {
            let n = rng.below(12);
            Json::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' { c as char } else { 'π' }
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn json_roundtrips_random_values() {
    let mut rng = Rng::new(1001);
    for _ in 0..500 {
        let v = random_json(&mut rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("failed to reparse {text}: {e}"));
        assert_eq!(back, v, "{text}");
    }
}

#[test]
fn json_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(1002);
    for _ in 0..2000 {
        let n = rng.below(64);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must return, not panic
        }
    }
}

#[test]
fn json_parser_never_panics_on_mutated_valid_input() {
    let mut rng = Rng::new(1003);
    let base = r#"{"layers":[{"name":"conv1","w_shape":[3,3,3,16],"x":1.5e-3}]}"#;
    for _ in 0..2000 {
        let mut b = base.as_bytes().to_vec();
        let i = rng.below(b.len());
        b[i] = rng.below(256) as u8;
        if let Ok(s) = std::str::from_utf8(&b) {
            let _ = Json::parse(s);
        }
    }
}

fn random_message(rng: &mut Rng) -> Message {
    // Tensor payloads are opaque byte slabs on the wire; the protocol
    // invariant is that the slab length is valid for the frame's codec tag
    // (fp32: 4-aligned, fp16: 2-aligned, int8: valid chunked framing) —
    // `CodecId::wire_len` produces such a length for any element count.
    let codec = CodecId::ALL[rng.below(3)];
    let n = codec.wire_len(4 * rng.below(200));
    let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
    // v4 sync frames: any mode; a staleness bound only under ssp (the
    // decoder rejects it elsewhere — covered separately below).
    let sync_mode = SyncMode::ALL[rng.below(3)];
    let sync_bound =
        if sync_mode == SyncMode::Ssp { rng.below(1 << 10) as u32 } else { 0 };
    // v5 registration frames: an edge role always announces exactly one
    // worker; a regional aggregator any non-zero group size (the decoder
    // rejects everything else — covered separately below).
    let role = if rng.bool() { PeerRole::Regional } else { PeerRole::Edge };
    let agg_workers =
        if role == PeerRole::Edge { 1 } else { 1 + rng.below(64) as u32 };
    // v6 snapshot frames: the reply always names a non-zero fleet size
    // (the decoder rejects 0 — covered separately below).
    let snap_workers = 1 + rng.below(64) as u32;
    match rng.below(16) {
        0 => Message::Pull { iter: rng.next_u64(), lo: rng.below(100) as u32, hi: rng.below(100) as u32 },
        1 => Message::PullReply {
            iter: rng.next_u64(),
            lo: 0,
            hi: 5,
            applied: rng.next_u64(),
            codec,
            data,
        },
        2 => Message::Push { iter: rng.next_u64(), lo: 1, hi: 3, codec, data },
        3 => Message::PushAck { iter: rng.next_u64(), lo: 0, hi: 0 },
        4 => Message::Hello {
            worker: rng.below(64) as u32,
            version: rng.below(1 << 16) as u16,
        },
        5 => Message::HelloAck {
            workers: rng.below(64) as u32,
            version: rng.below(1 << 16) as u16,
        },
        6 => Message::CodecPropose { pref: CodecId::ALL[rng.below(3)] },
        7 => Message::CodecAgree { codec: CodecId::ALL[rng.below(3)] },
        8 => Message::SyncPropose { mode: sync_mode, bound: sync_bound },
        9 => Message::SyncAgree { mode: sync_mode, bound: sync_bound },
        10 => Message::AggHello {
            role,
            group: rng.below(1 << 10) as u32,
            workers: agg_workers,
            version: rng.below(1 << 16) as u16,
        },
        11 => Message::SnapshotReq {
            lo: rng.below(100) as u32,
            hi: rng.below(100) as u32,
        },
        12 => Message::SnapshotReply {
            iter: rng.next_u64(),
            lo: 0,
            hi: 5,
            workers: snap_workers,
            codec,
            data,
        },
        // v7 clock frames: all three timestamps are opaque u64 nanos.
        13 => Message::ClockProbe { t1: rng.next_u64() },
        14 => Message::ClockReply {
            t1: rng.next_u64(),
            t2: rng.next_u64(),
            t3: rng.next_u64(),
        },
        _ => Message::Shutdown,
    }
}

#[test]
fn wire_protocol_roundtrips_random_messages() {
    let mut rng = Rng::new(1004);
    for _ in 0..1000 {
        let m = random_message(&mut rng);
        let enc = m.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4, "length prefix wrong for {m:?}");
        assert_eq!(Message::decode(&enc[4..]).unwrap(), m);
    }
}

#[test]
fn wire_decoder_never_panics_on_corruption() {
    let mut rng = Rng::new(1005);
    for _ in 0..2000 {
        let m = random_message(&mut rng);
        let mut enc = m.encode();
        // Random single-byte corruption + random truncation.
        if enc.len() > 4 {
            let i = 4 + rng.below(enc.len() - 4);
            enc[i] ^= 1 << rng.below(8);
            let cut = 4 + rng.below(enc.len() - 4 + 1);
            let _ = Message::decode(&enc[4..cut.max(5).min(enc.len())]);
            let _ = Message::decode(&enc[4..]); // must return, not panic
        }
    }
}

#[test]
fn wire_decoder_never_panics_on_random_bytes() {
    let mut rng = Rng::new(1006);
    for _ in 0..2000 {
        let n = rng.below(128);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = Message::decode(&bytes);
    }
}

/// One well-formed exemplar per frame tag the protocol defines.
/// `dynalint`'s wire check pins tag uniqueness and decoder coverage
/// statically; the properties below drive the same matrix dynamically, so
/// a new frame variant fails here until it gets an exemplar (the coverage
/// assertion) and survives the mutation battery.
fn exemplar_messages() -> Vec<Message> {
    let codec = CodecId::Fp32;
    let data = vec![0u8; codec.wire_len(8)];
    vec![
        Message::Pull { iter: 7, lo: 0, hi: 3 },
        Message::PullReply {
            iter: 7,
            lo: 0,
            hi: 3,
            applied: 7,
            codec,
            data: data.clone(),
        },
        Message::Push { iter: 7, lo: 0, hi: 3, codec, data: data.clone() },
        Message::PushAck { iter: 7, lo: 0, hi: 3 },
        Message::Hello { worker: 0, version: PROTOCOL_VERSION },
        Message::HelloAck { workers: 1, version: PROTOCOL_VERSION },
        Message::Shutdown,
        Message::CodecPropose { pref: CodecId::Fp16 },
        Message::CodecAgree { codec: CodecId::Int8 },
        Message::SyncPropose { mode: SyncMode::Ssp, bound: 4 },
        Message::SyncAgree { mode: SyncMode::Bsp, bound: 0 },
        // v5/v6: appended last so the positional mutation offsets above
        // stay stable across protocol bumps.
        Message::AggHello {
            role: PeerRole::Regional,
            group: 9,
            workers: 4,
            version: PROTOCOL_VERSION,
        },
        Message::SnapshotReq { lo: 0, hi: 3 },
        Message::SnapshotReply { iter: 7, lo: 0, hi: 3, workers: 4, codec, data },
        // v7: the clock-alignment pair, again appended last.
        Message::ClockProbe { t1: 17 },
        Message::ClockReply { t1: 17, t2: 19, t3: 23 },
    ]
}

/// Every frame tag × {truncated, oversized, bad embedded tag} decodes to
/// an error — never a panic, never a silent reinterpretation.
#[test]
fn decoder_rejects_mutations_of_every_frame_tag() {
    let msgs = exemplar_messages();

    // Coverage gate: the exemplars span exactly the contiguous tag space
    // 1..=16 with no duplicates, so adding a frame to the protocol forces
    // an exemplar (and the mutations below) for it.
    let mut tags: Vec<u8> = msgs.iter().map(|m| m.opcode()).collect();
    tags.sort_unstable();
    tags.dedup();
    assert_eq!(tags, (1u8..=16).collect::<Vec<u8>>());

    for m in &msgs {
        let enc = m.encode();
        let payload = &enc[4..];
        // Truncated: no strict prefix of a frame is itself a frame.
        for cut in 0..payload.len() {
            assert!(
                Message::decode(&payload[..cut]).is_err(),
                "{m:?} truncated to {cut} bytes decoded"
            );
        }
        // Oversized: the decoder consumes exactly the frame and rejects
        // leftovers, even when the tail looks like plausible data.
        for extra in [1usize, 7] {
            let mut fat = payload.to_vec();
            fat.resize(payload.len() + extra, 0xAA);
            assert!(
                Message::decode(&fat).is_err(),
                "{m:?} with {extra} trailing bytes decoded"
            );
        }
    }

    // Bad embedded tags: codec tag 3 and sync mode tag 3 name nothing.
    // Tensor frames carry the codec tag in the top 2 bits of the slab
    // length field (payload offset 25 for PullReply, 17 for Push, 21 for
    // SnapshotReply — plus the 4-byte length prefix and 3 for the
    // little-endian MSB).
    for (m, off) in [(&msgs[1], 25usize), (&msgs[2], 17), (&msgs[13], 21)] {
        let mut enc = m.encode();
        enc[4 + off + 3] |= 0xC0;
        assert!(
            Message::decode(&enc[4..]).is_err(),
            "{m:?} with forged slab codec tag decoded"
        );
    }
    // CodecPropose/CodecAgree (byte codec tag) and SyncPropose/SyncAgree
    // (byte mode tag) carry their tag at payload offset 1.
    for m in [&msgs[7], &msgs[8], &msgs[9], &msgs[10]] {
        let mut enc = m.encode();
        enc[5] = 3;
        assert!(
            Message::decode(&enc[4..]).is_err(),
            "{m:?} with forged negotiation tag decoded"
        );
    }
    // AggHello (v5) layout: role u8 at payload offset 1, group u32 at 2,
    // workers u32 at 6 — so enc[5] is the role tag and enc[10..14] the
    // worker count. Role tag 2 names nothing; a zero worker count and an
    // edge role announcing a whole group are both malformed.
    let agg = &msgs[11];
    assert_eq!(agg.opcode(), 12, "exemplar order drifted");
    let mut enc = agg.encode();
    enc[5] = 2;
    assert!(
        Message::decode(&enc[4..]).is_err(),
        "AggHello with unknown role tag decoded"
    );
    let mut enc = agg.encode();
    enc[10..14].fill(0);
    assert!(
        Message::decode(&enc[4..]).is_err(),
        "AggHello with zero worker count decoded"
    );
    let mut enc = agg.encode();
    enc[5] = 0; // edge role, but the exemplar announces 4 workers
    assert!(
        Message::decode(&enc[4..]).is_err(),
        "edge-role AggHello announcing a group decoded"
    );
    // SnapshotReply (v6) layout: iter u64 at payload offset 1, lo/hi u32
    // at 9/13, workers u32 at 17 — so enc[21..25] is the fleet size. A
    // snapshot from an empty fleet is malformed.
    let snap = &msgs[13];
    assert_eq!(snap.opcode(), 14, "exemplar order drifted");
    let mut enc = snap.encode();
    enc[21..25].fill(0);
    assert!(
        Message::decode(&enc[4..]).is_err(),
        "SnapshotReply with zero fleet size decoded"
    );
}

/// v4 sync frames under random payload fuzzing: the decoder accepts
/// exactly the well-formed (mode, bound) pairs — any bound under ssp, only
/// 0 under bsp/asp, no mode tag past 2 — and never panics on the rest.
#[test]
fn sync_frames_reject_malformed_staleness_bounds() {
    let mut rng = Rng::new(1007);
    for _ in 0..4000 {
        let op = if rng.bool() { 10u8 } else { 11 };
        let tag = rng.below(5) as u8;
        let bound = match rng.below(3) {
            0 => 0u32,
            1 => rng.below(8) as u32,
            _ => rng.next_u64() as u32,
        };
        let mut frame = vec![op, tag];
        frame.extend_from_slice(&bound.to_le_bytes());
        let decoded = Message::decode(&frame); // must return, not panic
        let well_formed = match SyncMode::from_tag(tag) {
            Some(SyncMode::Ssp) => true,
            Some(_) => bound == 0,
            None => false,
        };
        assert_eq!(
            decoded.is_ok(),
            well_formed,
            "op {op} mode tag {tag} bound {bound}: {decoded:?}"
        );
        if let Ok(m) = decoded {
            // Whatever decodes must re-encode to the same bytes.
            let enc = m.encode();
            assert_eq!(&enc[4..], &frame[..]);
        }
    }
    // Truncated sync frames fail cleanly too.
    assert!(Message::decode(&[10, 1]).is_err());
    assert!(Message::decode(&[11, 1, 0]).is_err());
}
