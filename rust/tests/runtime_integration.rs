//! PJRT runtime integration — requires `make artifacts` (tests no-op with a
//! notice otherwise, so `cargo test` works in a fresh checkout).

use dynacomm::runtime::{artifacts_available, RuntimeClient, Tensor};
use dynacomm::util::rng::Rng;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn client() -> Option<RuntimeClient> {
    if !artifacts_available(DIR) {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(RuntimeClient::load(DIR).expect("loading artifacts"))
}

fn random_batch(rt: &RuntimeClient, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut shape = vec![rt.manifest.batch];
    shape.extend(&rt.manifest.input_shape);
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect())
}

/// Layer-wise forward composition must equal the monolithic `full_fwd`
/// lowering — the composition the PS worker performs is numerically the
/// same model.
#[test]
fn layerwise_composition_matches_monolithic_forward() {
    let Some(rt) = client() else { return };
    let params = rt.initial_params().unwrap();
    let x = random_batch(&rt, 1);

    let mut act = x.clone();
    for l in 0..rt.manifest.depth() {
        let (w, b) = &params[l];
        act = rt.layer_fwd(l, w, b, &act).unwrap();
    }
    let mono = rt.full_fwd(&params, &x).unwrap();
    assert_eq!(act.shape, mono.shape);
    for (a, m) in act.data.iter().zip(&mono.data) {
        assert!((a - m).abs() < 1e-3 * (1.0 + m.abs()), "{a} vs {m}");
    }
}

/// Uniform logits ⇒ loss = ln(10); glogits rows sum to ~0.
#[test]
fn loss_head_sanity() {
    let Some(rt) = client() else { return };
    let b = rt.manifest.batch;
    let logits = Tensor::zeros(vec![b, 10]);
    let mut onehot = Tensor::zeros(vec![b, 10]);
    for r in 0..b {
        onehot.data[r * 10 + r % 10] = 1.0;
    }
    let (loss, glogits) = rt.loss(&logits, &onehot).unwrap();
    assert!((loss - 10f32.ln()).abs() < 1e-4, "{loss}");
    for r in 0..b {
        let s: f32 = glogits.data[r * 10..(r + 1) * 10].iter().sum();
        assert!(s.abs() < 1e-5);
    }
}

/// Backward gradients: finite-difference check of the loss through one
/// layer (fc2 — cheap) against the exported bwd artifact.
#[test]
fn layer_bwd_matches_finite_difference() {
    let Some(rt) = client() else { return };
    let depth = rt.manifest.depth();
    let l = depth - 1; // fc2: input (b, 128), small
    let params = rt.initial_params().unwrap();
    let (w, b) = &params[l];
    let mut rng = Rng::new(3);
    let bsz = rt.manifest.batch;
    let x = Tensor::new(
        vec![bsz, 128],
        (0..bsz * 128).map(|_| rng.normal() as f32 * 0.5).collect(),
    );
    let mut onehot = Tensor::zeros(vec![bsz, 10]);
    for r in 0..bsz {
        onehot.data[r * 10 + (r * 3) % 10] = 1.0;
    }

    let loss_of = |w: &Tensor| -> f32 {
        let y = rt.layer_fwd(l, w, b, &x).unwrap();
        rt.loss(&y, &onehot).unwrap().0
    };

    // Analytic gradient through the artifact chain.
    let y = rt.layer_fwd(l, w, b, &x).unwrap();
    let (_, glogits) = rt.loss(&y, &onehot).unwrap();
    let (gw, _, _) = rt.layer_bwd(l, w, b, &x, &glogits).unwrap();

    // Central differences on a few weight entries.
    let eps = 1e-3f32;
    for &idx in &[0usize, 77, 500, 1200] {
        let mut wp = w.clone();
        wp.data[idx] += eps;
        let mut wm = w.clone();
        wm.data[idx] -= eps;
        let fd = (loss_of(&wp) - loss_of(&wm)) / (2.0 * eps);
        let an = gw.data[idx];
        assert!(
            (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
            "idx {idx}: fd={fd} analytic={an}"
        );
    }
}

/// Initial parameter files parse to the manifest shapes.
#[test]
fn initial_params_match_shapes() {
    let Some(rt) = client() else { return };
    let params = rt.initial_params().unwrap();
    assert_eq!(params.len(), rt.manifest.depth());
    for ((w, b), spec) in params.iter().zip(&rt.manifest.layers) {
        assert_eq!(w.shape, spec.w_shape, "{}", spec.name);
        assert_eq!(b.shape, spec.b_shape, "{}", spec.name);
        assert!(w.data.iter().all(|v| v.is_finite()));
    }
}
